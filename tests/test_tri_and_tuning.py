"""Tri-schedule cohesion kernel + block-size autotuner tests.

Covers this PR's acceptance criteria: the upper-triangular pass-2 schedule
matches the entry-wise ties='ignore' reference (interpret mode, padded and
non-block-multiple n), the jnp fallback matches the kernel, prime-ish dims
pad instead of degrading to block=1 grids, and the tuning cache round-trips.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pald, reference
from repro.kernels import ops, ref
from repro.kernels.pald_cohesion_tri import cohesion_tri_pallas
from repro.tuning import autotune

from conftest import euclidean_distance_matrix


def _D(rng, n, dtype=np.float32):
    X = rng.normal(size=(n, 4))
    return euclidean_distance_matrix(X).astype(dtype)


# ---------------------------------------------------------------------------
# tri cohesion kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,blk,blkz", [
    (32, 8, 8), (32, 16, 32), (64, 16, 16), (64, 32, 64), (96, 32, 96),
])
def test_cohesion_tri_kernel_sweep(rng, n, blk, blkz):
    D = jnp.asarray(_D(rng, n))
    W = ref.weights_ref(ref.focus_ref(D))
    C = cohesion_tri_pallas(D, W, block=blk, block_z=blkz, interpret=True)
    np.testing.assert_allclose(
        np.asarray(C), np.asarray(ref.cohesion_ref(D, W)), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("n", [37, 40, 100])
def test_cohesion_tri_via_ops_nonmultiple(rng, n):
    """ops pads non-block-multiple n internally; result stays exact."""
    D = jnp.asarray(_D(rng, n))
    W = ref.weights_ref(ref.focus_ref(D))
    Cref = ref.cohesion_ref(D, W)
    for impl in ("interpret", "jnp"):
        C = ops.cohesion_from_weights(D, W, block=16, block_z=16, impl=impl,
                                      schedule="tri")
        np.testing.assert_allclose(np.asarray(C), np.asarray(Cref),
                                   rtol=1e-5, atol=1e-6)


def test_tri_jnp_matches_interpret(rng):
    D = jnp.asarray(_D(rng, 64))
    Ci = ops.pald_tri(D, block=16, block_z=32, impl="interpret")
    Cj = ops.pald_tri(D, block=16, block_z=32, impl="jnp")
    np.testing.assert_allclose(np.asarray(Ci), np.asarray(Cj),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [37, 64])
def test_api_tri_schedule_matches_reference(rng, n):
    """pald.cohesion(method='kernel', schedule='tri') vs Algorithm 1 with
    ties='ignore' — the tri schedule's complement trick implements exactly
    those tie semantics; on tie-free input every path agrees."""
    D = _D(rng, n, np.float64)
    Cr = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
    C = pald.cohesion(jnp.asarray(D), method="kernel", schedule="tri", block=16)
    np.testing.assert_allclose(np.asarray(C), Cr, rtol=1e-4, atol=1e-6)


def test_pald_tri_equals_dense_kernel_pipeline(rng):
    D = jnp.asarray(_D(rng, 64))
    Cd = ops.pald(D, block=16, block_z=32, impl="interpret")
    Ct = ops.pald(D, block=16, block_z=32, impl="interpret", schedule="tri")
    np.testing.assert_allclose(np.asarray(Ct), np.asarray(Cd),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# prime-ish dims: pad, don't degrade (regression for the block=1 grid)
# ---------------------------------------------------------------------------
def test_block_and_pad_prime_dims():
    b, m = ops._block_and_pad(97, 32)
    assert (b, m) == (32, 128)          # padded, not block=1
    b, m = ops._block_and_pad(194, 32)  # 2 * 97: best divisor is 2
    assert (b, m) == (32, 224)
    b, m = ops._block_and_pad(96, 50)   # benign shrink to a divisor stays
    assert (b, m) == (48, 96)
    b, m = ops._block_and_pad(7, 32)    # single block, no grid to degrade
    assert (b, m) == (7, 7)


def test_prime_n_kernels_exact(rng):
    n = 97
    D = jnp.asarray(_D(rng, n))
    U = ops.focus_general(D, D, D, block=32, block_z=32, impl="interpret")
    np.testing.assert_allclose(np.asarray(U), np.asarray(ref.focus_ref(D)))
    W = ref.weights_ref(ref.focus_ref(D))
    C = ops.cohesion_general(D, D, D, W, block=32, block_z=32, impl="interpret")
    np.testing.assert_allclose(np.asarray(C), np.asarray(ref.cohesion_ref(D, W)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# autotuner cache
# ---------------------------------------------------------------------------
def test_cache_roundtrip(tmp_path):
    cache = str(tmp_path / "tune.json")
    autotune.save_entry("cpu", "jnp", 1024, "cohesion_tri",
                        {"block": 64, "block_z": 256, "seconds": 0.5},
                        path=cache)
    # write -> reload -> same block choice
    assert autotune.resolve_blocks(1024, "cohesion_tri", impl="jnp",
                                   backend="cpu", path=cache) == (64, 256)
    # nearest-n fallback (log-space): 2048 resolves to the 1024 entry
    assert autotune.resolve_blocks(2048, "cohesion_tri", impl="jnp",
                                   backend="cpu", path=cache) == (64, 256)
    # a different pass misses the cache and takes the size-aware default
    blk, bz = autotune.resolve_blocks(1024, "focus", impl="jnp",
                                      backend="cpu", path=cache)
    assert (blk, bz) == (128, 512)


def test_tune_writes_cache_and_resolves(tmp_path):
    cache = str(tmp_path / "tune.json")
    rec = autotune.tune(32, "cohesion_tri", impl="jnp",
                        blocks=(8, 16), blocks_z=(16,), path=cache, iters=1)
    assert {"block", "block_z", "seconds", "grid"} <= set(rec)
    got = autotune.resolve_blocks(32, "cohesion_tri", impl="jnp", path=cache)
    assert got == (rec["block"], rec["block_z"])


def test_method_crossover_cache(tmp_path):
    cache = str(tmp_path / "tune.json")
    # cold cache: seed heuristic
    assert autotune.method_for(64, backend="cpu", path=cache) == "dense"
    assert autotune.method_for(1024, backend="cpu", path=cache) == "triplet"
    # measured crossover wins over the heuristic
    autotune.save_entry("cpu", "-", 1024, "method",
                        {"method": "pairwise", "timings": {}}, path=cache)
    assert autotune.method_for(1024, backend="cpu", path=cache) == "pairwise"
    assert autotune.method_for(900, backend="cpu", path=cache) == "pairwise"


def test_block_auto_paths(tmp_path, rng, monkeypatch):
    """block='auto' flows end to end through ops and the public API."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    D = jnp.asarray(_D(rng, 48))
    U = ops.focus(D, block="auto", block_z="auto", impl="jnp")
    np.testing.assert_allclose(np.asarray(U), np.asarray(ref.focus_ref(D)))
    C = pald.cohesion(D, method="kernel", schedule="tri", block="auto")
    Cd = pald.cohesion(D, method="dense")
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cd),
                               rtol=1e-5, atol=1e-6)
