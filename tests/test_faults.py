"""Fault-injection tests: guarded execution under forced failures.

Driven by the ``repro.testing.faults`` harness, this file proves the
acceptance contract of the resilience layer (DESIGN.md §13):

* with Pallas forced to fail, every registered (kind, method, schedule)
  cell in fallback mode returns results bitwise-equal to its un-faulted
  run, with a recorded degradation event where a degradation happened;
* ``on_error="raise"`` (the default) re-raises the original exception;
* injected OOM on a batched call succeeds after halving ``batch`` —
  bitwise-equal, because re-chunking is a pure re-partition;
* exhausting the whole chain raises ``FallbackExhausted`` whose message
  names the cell, the original error, and every attempted step.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine, pald, resilience
from repro.testing import faults


@pytest.fixture(autouse=True)
def _fresh_harness():
    faults.reset()
    yield
    faults.reset()


def _D(n=17, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 3))
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return jnp.asarray(D, jnp.float32)


def _X(n=17, d=3, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                       jnp.float32)


def _plan_for_cell(kind, method, schedule, *, n=17, d=3,
                   on_error="fallback"):
    kw = dict(kind=kind, method=method, schedule=schedule, n=n,
              on_error=on_error)
    if method == "knn":
        kw["k"] = 5
    if kind == "features":
        kw["d"] = d
    return pald.plan(**kw)


def _input_for(kind):
    return _X() if kind == "features" else _D()


CELLS = engine.available_executors()
_IDS = ["-".join(c) for c in CELLS]


# ---------------------------------------------------------------------------
# the acceptance sweep: every registered cell
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", CELLS, ids=_IDS)
def test_pallas_fault_bitwise_identical_everywhere(cell):
    """Failing every pallas-impl call leaves every cell's fallback-mode
    result bitwise-equal to its un-faulted run — off-TPU trivially (no
    pallas dispatch, no degradation), on TPU via the recorded chain."""
    x = _input_for(cell[0])
    baseline = np.asarray(_plan_for_cell(*cell).execute(x))
    p = _plan_for_cell(*cell)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.fail_kernel(impl="pallas"):
            out = np.asarray(p.execute(x))
    np.testing.assert_array_equal(out, baseline)
    events = p.explain()["degradations"]
    if jax.default_backend() == "tpu":
        assert events and events[-1]["cause"] == "executor-failure"
    else:
        assert events == []


@pytest.mark.parametrize("cell", CELLS, ids=_IDS)
def test_primary_failure_walks_chain_with_identical_semantics(cell):
    """Kill each cell's primary dispatch once: the chain must rescue it,
    record exactly one degradation event, and the result must be
    bitwise-equal to an un-faulted run of the very step that rescued it
    (the identical-ties/normalize re-execution contract) and tightly close
    to the primary's own un-faulted answer."""
    x = _input_for(cell[0])
    clean = _plan_for_cell(*cell)
    baseline = np.asarray(clean.execute(x))
    p = _plan_for_cell(*cell)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("engine.execute", times=1) as rule:
            out = np.asarray(p.execute(x))
    assert rule.trips == 1
    events = p.explain()["degradations"]
    assert len(events) == 1
    evt = events[0]
    assert evt["cause"] == "executor-failure"
    assert evt["cell"] == cell
    assert "injected fault" in evt["error"]
    # bitwise against the rescuing step, re-run without faults
    step = next(s for s in resilience.chain_for(p)
                if s.label == evt["fallback"])
    expected = np.asarray(step.run(x, clean, None))
    np.testing.assert_array_equal(out, expected)
    # and numerically the same answer as the primary would have given
    np.testing.assert_allclose(out, baseline, rtol=1e-5, atol=1e-6)


def test_fallback_plan_without_faults_changes_nothing():
    D = _D()
    strict = np.asarray(pald.cohesion(D, method="kernel"))
    p = pald.plan(D, method="kernel", on_error="fallback")
    np.testing.assert_array_equal(np.asarray(p.execute(D)), strict)
    assert p.explain()["degradations"] == []


# ---------------------------------------------------------------------------
# strict mode: pre-existing semantics, untouched
# ---------------------------------------------------------------------------
def test_strict_mode_reraises_the_original_exception():
    D = _D()
    with faults.failing("engine.execute",
                       exc=lambda: RuntimeError("kernel exploded")):
        with pytest.raises(RuntimeError, match="kernel exploded"):
            pald.cohesion(D, method="kernel")  # on_error defaults to raise


def test_strict_mode_does_not_retry_oom():
    B = jnp.stack([_D(seed=s) for s in range(4)])
    p = pald.plan(_D(), method="kernel", batch=4)
    with faults.simulate_oom(max_batch=1):
        with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
            p.execute(B)


def test_unknown_on_error_rejected_at_plan_time():
    with pytest.raises(ValueError, match="on_error"):
        pald.plan(n=16, on_error="retry")
    with pytest.raises(ValueError, match="on_error"):
        engine.plan_local(16, on_error="never")


# ---------------------------------------------------------------------------
# OOM-aware batching
# ---------------------------------------------------------------------------
def test_oom_halves_batch_until_it_fits_bitwise():
    B = jnp.stack([_D(seed=s) for s in range(5)])
    clean = pald.plan(_D(), method="kernel", batch=4, on_error="fallback")
    baseline = np.asarray(clean.execute(B))
    p = pald.plan(_D(), method="kernel", batch=4, on_error="fallback")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.simulate_oom(max_batch=1):  # "device" fits 1 item
            out = np.asarray(p.execute(B))
    np.testing.assert_array_equal(out, baseline)  # re-chunking is bitwise
    events = p.explain()["degradations"]
    assert [e["cause"] for e in events] == ["oom", "oom"]  # 4 -> 2 -> 1
    assert [e["batch"] for e in events] == [2, 1]


def test_oom_at_the_floor_degrades_to_the_chain():
    B = jnp.stack([_D(seed=s) for s in range(4)])
    clean = pald.plan(_D(), method="kernel", batch=4, on_error="fallback")
    baseline = np.asarray(clean.execute(B))
    p = pald.plan(_D(), method="kernel", batch=4, on_error="fallback")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.simulate_oom():  # every batched call OOMs, batch=1 too
            out = np.asarray(p.execute(B))
    causes = [e["cause"] for e in p.explain()["degradations"]]
    assert "oom-floor" in causes  # the retry floor was hit and recorded
    final = p.explain()["degradations"][-1]
    # only the reference oracle doesn't go through the batch layer
    assert final["cause"] == "executor-failure"
    assert final["fallback"] == "reference"
    np.testing.assert_allclose(out, baseline, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# exhaustion: the error message is the debugging surface
# ---------------------------------------------------------------------------
def test_fallback_exhausted_names_cell_cause_and_every_step():
    D = _D()
    p = pald.plan(D, method="kernel", on_error="fallback")
    with faults.failing(""):  # every site: primary, chain steps, reference
        with pytest.raises(resilience.FallbackExhausted) as ei:
            p.execute(D)
    msg = str(ei.value)
    for frag in (
        "every fallback failed for cell",
        "('distance', 'kernel', 'dense')",
        "primary raised RuntimeError: injected fault",
        "degradation chain attempted",
        "impl:jnp",
        "method:triplet",
        "method:dense",
        "reference",
    ):
        assert frag in msg, f"missing {frag!r} in {msg!r}"
    # chained from the original failure: the root cause stays on the trace
    assert isinstance(ei.value.__cause__, RuntimeError)


def test_features_chain_exhausts_when_distance_frontend_is_dead():
    """Every non-fused features path (materialize compositions AND the
    reference oracle) funnels through cdist — killing it must exhaust."""
    X = _X()
    p = pald.plan(X, kind="features", method="pairwise", on_error="fallback")
    with faults.failing("features.cdist"):
        with pytest.raises(resilience.FallbackExhausted) as ei:
            p.execute(X)
    assert "('features', 'pairwise', 'dense')" in str(ei.value)


def test_knn_chain_is_impl_only():
    """No other path shares knn's sparse semantics: its chain must never
    degrade onto a dense method (which would silently change cost and,
    below k=n-1, values).  Since ISSUE 9 the chain ends on the
    ``select:chunked`` rung — row-chunked ``lax.top_k`` selection with
    jnp cohesion — which keeps the sparse semantics and is the smallest
    machinery that still answers."""
    for kind in ("distance", "features"):
        p = _plan_for_cell(kind, "knn", "dense")
        labels = [s.label for s in resilience.chain_for(p)]
        assert labels and labels[-1] == "select:chunked"
        assert all(lb.startswith("impl:") for lb in labels[:-1])
        assert "reference" not in labels  # the dense oracle never rescues knn


# ---------------------------------------------------------------------------
# ISSUE 9: the fused select->cohere sites degrade bitwise
# ---------------------------------------------------------------------------
def _knn_features_plan(on_error="fallback"):
    return pald.plan(kind="features", method="knn", n=33, d=3, k=5,
                     on_error=on_error)


def test_fused_selection_fault_rescued_bitwise():
    """Kill the fused jnp select->cohere program (and the interpret rung
    behind it): the terminal ``select:chunked`` rung must answer, bitwise
    — chunked selection is a pure re-partition of the same per-row
    ``lax.top_k`` contract, and the cohesion tile body is unchanged."""
    x = _X(n=33)
    baseline = np.asarray(_knn_features_plan().execute(x))
    p = _knn_features_plan()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("ops.select_cohere", match={"select": "jnp"}), \
             faults.failing("ops.select_cohere",
                            match={"select": "interpret"}):
            out = np.asarray(p.execute(x))
    np.testing.assert_array_equal(out, baseline)
    events = p.explain()["degradations"]
    assert events and events[-1]["fallback"] == "select:chunked"


def test_topk_select_fault_rescued_bitwise():
    """The standalone selection site (``ops.topk_select``) is a
    registered fault point too: killing the jnp selection inside the
    primary leaves the rescue bitwise-identical."""
    x = _X(n=33)
    baseline = np.asarray(_knn_features_plan().execute(x))
    p = _knn_features_plan()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("ops.topk_select", match={"impl": "jnp"}):
            out = np.asarray(p.execute(x))
    np.testing.assert_array_equal(out, baseline)
    assert len(p.explain()["degradations"]) == 1


def test_terminal_selection_rung_answers_alone_bitwise():
    """Exhaust every rung above ``select:chunked`` for the features-knn
    cell: the row-chunked ``lax.top_k`` terminal rung must answer by
    itself, bitwise-equal to the un-faulted primary."""
    x = _X(n=33)
    baseline = np.asarray(_knn_features_plan().execute(x))
    p = _knn_features_plan()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("engine.execute", times=1), \
             faults.failing("resilience.step",
                            pred=lambda site, **c: str(
                                c.get("step", "")).startswith("impl:")):
            out = np.asarray(p.execute(x))
    np.testing.assert_array_equal(out, baseline)
    final = p.explain()["degradations"][-1]
    assert final["fallback"] == "select:chunked"


def test_selection_faults_raise_in_strict_mode():
    x = _X(n=33)
    p = _knn_features_plan(on_error="raise")
    with faults.failing("ops.select_cohere", match={"select": "jnp"}):
        with pytest.raises(RuntimeError, match="injected fault"):
            p.execute(x)


# ---------------------------------------------------------------------------
# degradation events + once-per-cause warnings
# ---------------------------------------------------------------------------
def test_degradation_warns_once_per_cause_then_stays_quiet():
    D = _D()
    p = pald.plan(D, method="kernel", on_error="fallback")
    with faults.failing("engine.execute"):
        with pytest.warns(resilience.DegradationWarning,
                          match="degraded to impl:jnp"):
            p.execute(D)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # any further warning -> failure
            p.execute(D)  # same cause again: logged in events, not warned
    assert len(p.explain()["degradations"]) == 2


def test_explain_surfaces_on_error_and_degradations():
    p = pald.plan(n=16, method="kernel", on_error="fallback")
    info = p.explain()
    assert info["on_error"] == "fallback"
    assert info["degradations"] == []
    # events are snapshots: mutating the returned list must not alias
    info["degradations"].append("junk")
    assert p.explain()["degradations"] == []


# ---------------------------------------------------------------------------
# distributed shard bodies route through the same guard
# ---------------------------------------------------------------------------
def test_distributed_shard_bodies_degrade_across_impls():
    from jax.sharding import Mesh

    from repro.core import distributed

    D = _D(n=32, seed=3)
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("dev",))
    baseline = np.asarray(
        distributed.pald_distributed(D, mesh, strategy="ring"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("ops.", match={"impl": "jnp"}) as rule:
            out = np.asarray(distributed.pald_distributed(
                D, mesh, strategy="ring", on_error="fallback"))
    assert rule.trips >= 1  # the shard bodies really hit the fault
    np.testing.assert_allclose(out, baseline, rtol=1e-5, atol=1e-6)


def test_distributed_strict_mode_still_raises():
    from jax.sharding import Mesh

    from repro.core import distributed

    D = _D(n=32, seed=3)
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("dev",))
    with faults.failing("ops.", match={"impl": "jnp"}):
        with pytest.raises(RuntimeError, match="injected fault"):
            distributed.pald_distributed(D, mesh, strategy="ring")


# ---------------------------------------------------------------------------
# weight functionals through the degradation chain: every rescue rung must
# re-enter with the SAME functional — a fallback that silently swapped the
# contribution algebra would "succeed" with different numbers
# ---------------------------------------------------------------------------
def _weight_plan_for_cell(cell, *, on_error="fallback"):
    kw = dict(kind=cell[0], method=cell[1], schedule=cell[2], n=17,
              weight="soft", on_error=on_error)
    if cell[1] == "knn":
        kw["k"] = 5
    if cell[0] == "features":
        kw["d"] = 3
    return pald.plan(**kw)


@pytest.mark.parametrize("cell", CELLS, ids=_IDS)
def test_chain_rescues_with_same_weight_functional(cell):
    """Kill each cell's primary dispatch under a NON-built-in functional:
    the rescuing step must carry the functional (plan.weight rides the
    dataclasses.replace-derived plans), so the rescue is bitwise-equal to
    an un-faulted run of that step and numerically equal to the primary."""
    x = _input_for(cell[0])
    clean = _weight_plan_for_cell(cell)
    baseline = np.asarray(clean.execute(x))
    p = _weight_plan_for_cell(cell)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("engine.execute", times=1) as rule:
            out = np.asarray(p.execute(x))
    assert rule.trips == 1
    events = p.explain()["degradations"]
    assert len(events) == 1 and events[0]["cause"] == "executor-failure"
    assert p.explain()["weight"] == "soft"
    step = next(s for s in resilience.chain_for(p)
                if s.label == events[0]["fallback"])
    expected = np.asarray(step.run(x, clean, None))
    np.testing.assert_array_equal(out, expected)
    np.testing.assert_allclose(out, baseline, rtol=1e-5, atol=1e-6)


def test_terminal_reference_rung_speaks_weight_functionals():
    """Exhaust everything above the terminal rung with weight='soft': the
    built-in numpy oracle cannot answer, so the rung must route to the
    jnp einsum oracle with the same functional — not error, not fall back
    to a built-in mode."""
    D = _D()
    baseline = np.asarray(pald.cohesion(D, method="dense", weight="soft"))
    p = pald.plan(D, method="kernel", weight="soft", on_error="fallback")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("engine.execute"), \
             faults.failing("resilience.step",
                            pred=lambda site, **c: str(
                                c.get("step", "")).startswith(
                                    ("impl:", "method:"))):
            out = np.asarray(p.execute(D))
    final = p.explain()["degradations"][-1]
    assert final["fallback"] == "reference"
    np.testing.assert_allclose(out, baseline, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# corrupted tuning state: provenance changes, values never
# ---------------------------------------------------------------------------
def test_corrupt_tuning_cache_changes_only_provenance(tmp_path, monkeypatch):
    cache = tmp_path / "blocktune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    D = _D(n=20, seed=7)
    p_fresh = pald.plan(D, method="kernel", block="auto")
    baseline = np.asarray(p_fresh.execute(D))
    assert p_fresh.explain()["block_source"] == "default"

    # truncated JSON: quarantined at load, resolution falls to the same
    # defaults -> bitwise-identical values
    cache.write_text('{"truncated": ')
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        p_corrupt = pald.plan(D, method="kernel", block="auto")
    np.testing.assert_array_equal(np.asarray(p_corrupt.execute(D)), baseline)
    assert p_corrupt.explain()["block_source"] == "default"
    assert list(tmp_path.glob("*.corrupt-*")), "corrupt file not quarantined"

    # wrong-typed record: provenance flips to quarantined:<key>, values not
    backend = jax.default_backend()
    bad = {"block": -8, "block_z": "nope"}
    faults.write_cache(str(cache), {
        f"{backend}|jnp|20|pald": bad,
        f"{backend}|interpret|20|pald": bad,
    })
    p_bad = pald.plan(D, method="kernel", block="auto")
    assert p_bad.explain()["block_source"].startswith("quarantined:")
    np.testing.assert_array_equal(np.asarray(p_bad.execute(D)), baseline)


# ---------------------------------------------------------------------------
# mesh-sharded knn rungs: a dead shard body re-enters single-device fused
# ---------------------------------------------------------------------------
_needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 host devices")


def _knn_mesh_plan(mesh, strategy=None, on_error="fallback"):
    return pald.plan(n=17, d=3, kind="features", k=5, mesh=mesh,
                     strategy=strategy, on_error=on_error)


def _test_mesh():
    from repro.launch import mesh as meshlib

    return meshlib.make_test_mesh((2, 2), ("rows", "cols"))


@_needs_devices
def test_mesh_body_fault_rescues_single_device_bitwise():
    """Kill one shard body mid-chain: the rescue must re-enter the
    single-device fused pipeline and answer bitwise-identically, and the
    degradation record must name the mesh cell that failed."""
    X = _X()
    baseline = np.asarray(pald.from_features(X, method="knn", k=5))
    p = _knn_mesh_plan(_test_mesh())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("distributed_knn.body"):
            out = np.asarray(p.execute(X))
    np.testing.assert_array_equal(out, baseline)
    (evt,) = p.explain()["degradations"]
    assert evt["fallback"] == "mesh:single-device"
    assert evt["mesh"] == (2, 2)
    assert evt["strategy"] == "2d"
    assert evt["cell"] == ("features", "knn", "dense")


@_needs_devices
@pytest.mark.parametrize("strategy", ["allgather", "ring", "2d"])
def test_mesh_fault_matches_strategy(strategy):
    """A fault armed for ONE strategy fires only on that strategy's body;
    the rescue works identically from any of them."""
    X = _X()
    baseline = np.asarray(pald.from_features(X, method="knn", k=5))
    p = _knn_mesh_plan(_test_mesh(), strategy=strategy)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("distributed_knn.body",
                            match={"strategy": strategy}):
            out = np.asarray(p.execute(X))
    np.testing.assert_array_equal(out, baseline)
    (evt,) = p.explain()["degradations"]
    assert evt["strategy"] == strategy
    assert evt["mesh"] == (2, 2)


@_needs_devices
def test_mesh_fault_strict_mode_raises():
    X = _X()
    p = _knn_mesh_plan(_test_mesh(), on_error="raise")
    with faults.failing("distributed_knn.dispatch"):
        with pytest.raises(RuntimeError, match="injected fault"):
            p.execute(X)


@_needs_devices
def test_mesh_rescue_survives_dead_primary_impl_too():
    """Mesh body dead AND the first single-device re-entry dead: the chain
    keeps walking (mesh:single-device -> impl rungs) and still answers
    bitwise, with the mesh cell recorded on the final event."""
    X = _X()
    baseline = np.asarray(pald.from_features(X, method="knn", k=5))
    p = _knn_mesh_plan(_test_mesh())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", resilience.DegradationWarning)
        with faults.failing("distributed_knn.body"), \
             faults.failing("resilience.step",
                            match={"step": "mesh:single-device"}):
            out = np.asarray(p.execute(X))
    np.testing.assert_array_equal(out, baseline)
    evt = p.explain()["degradations"][-1]
    assert evt["fallback"].startswith("impl:")
    assert evt["mesh"] == (2, 2)
