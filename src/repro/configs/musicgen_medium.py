"""musicgen-medium — 48L d_model=1536 24H (kv=24, i.e. MHA) d_ff=6144
vocab=2048 (EnCodec codebook).  Decoder-only over EnCodec tokens; the audio
frontend is a stub providing precomputed frame embeddings (per brief).
[arXiv:2306.05284; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    act="gelu",
    modality="audio",
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=2,
    subquadratic=False,
)
