"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The production topology is a TPU v5e pod of
16 x 16 = 256 chips; the multi-pod configuration is 2 such pods (512 chips)
with a leading "pod" axis whose links are the slow inter-pod interconnect.

Axis roles:
    pod    slow inter-pod axis: ZeRO-3 parameter sharding, PaLD z-streaming
    data   fast intra-pod axis: DP/FSDP, batch sharding
    model  fast intra-pod axis: TP/EP (heads, ff, experts, vocab)
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run entrypoint must set xla_force_host_platform_device_count "
            "before any jax import"
        )
    arr = np.asarray(devs[:n]).reshape(shape)
    return Mesh(arr, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")) -> Mesh:
    """Small mesh over however many host devices tests forced."""
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)
