"""Unit tests for the execution-plan engine (core/engine.py).

The conformance matrix (test_conformance.py) already proves every executor
numerically; this file pins the *plan layer* itself: resolution-once
semantics, knob validation at the one boundary, explain() provenance, the
registry contract, and the distributed ``plan_local`` consumer.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, pald


def _D(n=12, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 3))
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return jnp.asarray(D, jnp.float32)


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------
def test_plan_is_frozen_and_reusable():
    D = _D()
    p = pald.plan(D, method="triplet", block=8)
    with pytest.raises((AttributeError, TypeError)):  # frozen dataclass
        p.block = 16
    C1 = np.asarray(p.execute(D))
    C2 = np.asarray(p.execute(D))
    np.testing.assert_array_equal(C1, C2)
    # same plan, different data of the same shape
    C3 = np.asarray(p.execute(_D(seed=1)))
    assert C3.shape == C1.shape and not np.array_equal(C3, C1)


def test_plan_shape_only():
    p = pald.plan(n=1024, method="pairwise")
    assert p.n == 1024 and p.block is not None
    assert p.padded_n % p.block == 0
    pf = pald.plan(n=64, d=8, kind="features", metric="cosine")
    assert pf.method == "fused" and pf.d == 8
    with pytest.raises(ValueError):
        pald.plan(kind="features", n=64)  # d missing
    with pytest.raises(ValueError):
        pald.plan()  # nothing to key resolution on


def test_plan_auto_resolves_method_and_records_provenance():
    p = pald.plan(_D(), method="auto")
    assert p.method in ("dense", "pairwise", "triplet", "kernel")
    assert p.method_source in ("heuristic",) or p.method_source.startswith(
        ("cache:", "nearest:"))
    pt = pald.plan(_D(), schedule="tri")
    assert pt.method == "kernel" and pt.method_source == "schedule=tri"
    pe = pald.plan(_D(), method="triplet", block=8)
    assert pe.method_source == "explicit" and pe.block_source == "explicit"
    pa = pald.plan(_D(), method="triplet", block="auto")
    assert pa.block_source == "default" or pa.block_source.startswith(
        ("cache:", "nearest:"))


def test_explain_contract():
    D = _D()
    p = pald.plan(D, method="kernel", schedule="tri", block=8, block_z=8)
    info = p.explain()
    for key in ("kind", "method", "schedule", "impl", "block", "block_z",
                "ties", "normalize", "n", "padded_n", "padded_shape",
                "method_source", "block_source", "executor",
                "est_vmem_bytes_per_step"):
        assert key in info, key
    assert info["method"] == "kernel" and info["schedule"] == "tri"
    assert info["padded_n"] % 8 == 0
    assert info["executor"].startswith("repro.kernels.ops.")
    assert info["est_vmem_bytes_per_step"] > 0
    pf = pald.plan(n=32, d=4, kind="features")
    assert pf.explain()["padded_shape"][1] == 4


def test_auto_method_pinned_by_path_specific_knobs():
    """With method='auto', a dense-only or kernel-only knob pins the method
    deterministically — legality must never depend on the input size or on
    what the tuning cache happens to say on this machine."""
    D = _D()
    p = pald.plan(D, z_chunk=4)
    assert p.method == "dense" and p.method_source == "z_chunk"
    assert p.z_chunk == 4
    p = pald.plan(D, impl="jnp")
    assert p.method == "kernel" and p.method_source == "impl/block_z"
    p = pald.plan(D, block_z=8)
    assert p.method == "kernel" and p.block_z == 8
    with pytest.raises(ValueError, match="explicit method"):
        pald.plan(D, z_chunk=4, impl="jnp")  # pins contradict each other
    # "auto" tiles are NOT a kernel preference: the fully-automatic call
    # must still go through the measured method crossover
    p = pald.plan(D, block="auto", block_z="auto")
    assert p.method_source == "heuristic" or p.method_source.startswith(
        ("cache:", "nearest:"))


def test_block_z_auto_resolves_to_no_tile_on_jnp_blocked_paths():
    """block_z='auto' on pairwise/triplet/dense means 'pick for me', and
    the right pick is 'no z tile' — explain() shows None with no z
    provenance, while an explicit int stays an error (contradiction)."""
    D = _D()
    for method in ("pairwise", "triplet"):
        p = pald.plan(D, method=method, block=8, block_z="auto")
        assert p.block_z is None and "z:" not in p.block_source
        with pytest.raises(ValueError, match="block_z"):
            pald.plan(D, method=method, block_z=8)
    p = pald.plan(D, method="dense", block_z="auto")
    assert p.block_z is None
    # kernel genuinely has a z tile: explicit block + auto z keeps both,
    # with provenance crediting only the resolved half
    p = pald.plan(D, method="kernel", block=8, block_z="auto")
    assert p.block == 8 and p.block_z is not None
    assert p.block_source.startswith("explicit; z:")


def test_plan_validation_rejects_contradictions():
    D = _D()
    cases = [
        (dict(method="nope"), "unknown method"),
        (dict(schedule="diag"), "unknown schedule"),
        (dict(kind="graphs"), "unknown kind"),
        (dict(schedule="tri", method="triplet"), "only available"),
        (dict(method="dense", block_z=8), "block_z"),
        (dict(method="pairwise", block_z=8), "block_z"),
        (dict(method="triplet", z_chunk=4), "z_chunk"),
        (dict(method="pairwise", impl="jnp"), "impl"),
        (dict(metric="cosine"), "metric"),  # metric on distance kind
        (dict(batch=0), "batch"),
    ]
    for kw, frag in cases:
        with pytest.raises(ValueError, match=frag):
            pald.plan(D, **kw)


def test_validation_errors_name_the_legal_alternatives():
    """Knob-validation errors are the API's discovery surface: each one
    must say what WOULD be legal, not just reject (ISSUE 5)."""
    D = _D()
    cases = [
        # (kwargs, fragments that must all appear in the message)
        (dict(method="dense", k=3),
         ["only valid with method='knn'", "drop k=", "method='knn'"]),
        (dict(method="knn"), ["needs k=", "1 <= k <= n-1"]),
        (dict(method="knn", k=3, schedule="tri"),
         ["only available for method='kernel'", "drop schedule="]),
        (dict(method="triplet", z_chunk=4),
         ["only applies to method='dense'", "method='dense'"]),
        (dict(method="pairwise", impl="jnp"), ["kernel/fused/knn"]),
        (dict(method="knn", k=3, block_z=8), ["tune block="]),
        (dict(method="nope"), ["expected one of"]),
    ]
    for kw, frags in cases:
        with pytest.raises(ValueError) as ei:
            pald.plan(D, **kw)
        for frag in frags:
            assert frag in str(ei.value), (kw, frag, str(ei.value))


def test_always_on_input_checks():
    with pytest.raises(ValueError, match="square"):
        pald.cohesion(jnp.zeros((3, 4)))
    with pytest.raises(ValueError, match="diagonal"):
        pald.cohesion(jnp.eye(4))
    bad = np.zeros((3, 3))
    bad[1, 1] = np.nan  # nan diagonal counts as nonzero
    with pytest.raises(ValueError, match="diagonal"):
        pald.cohesion(jnp.asarray(bad))


def test_check_true_deep_validation():
    rng = np.random.default_rng(0)
    A = np.abs(rng.normal(size=(6, 6)))
    np.fill_diagonal(A, 0.0)
    with pytest.raises(ValueError, match="symmetric"):
        pald.cohesion(jnp.asarray(A), check=True)
    D = A + A.T
    assert pald.cohesion(jnp.asarray(D), check=True).shape == (6, 6)
    with pytest.raises(ValueError, match="negative"):
        pald.cohesion(jnp.asarray(-D), check=True)
    Dn = D.copy()
    Dn[0, 1] = Dn[1, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        pald.cohesion(jnp.asarray(Dn), check=True)
    with pytest.raises(ValueError, match="non-finite"):
        pald.from_features(jnp.asarray([[1.0, np.nan], [0.0, 1.0]]),
                           check=True)


def test_execute_rejects_mismatched_item_shape():
    p = pald.plan(_D(12), method="triplet", block=8)
    with pytest.raises(ValueError, match="does not match the"):
        p.execute(_D(10))


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------
def test_default_registry_covers_every_public_cell():
    cells = set(engine.available_executors())
    for m in ("dense", "pairwise", "triplet", "kernel"):
        assert ("distance", m, "dense") in cells
        assert ("features", m, "dense") in cells
    assert ("distance", "kernel", "tri") in cells
    assert ("features", "kernel", "tri") in cells
    assert ("features", "fused", "dense") in cells


def test_register_and_lookup_custom_executor():
    calls = []

    @engine.register_executor("test-kind", "noop")
    def _noop(x, plan):
        calls.append(plan.method)
        return x

    try:
        fn = engine.get_executor("test-kind", "noop", "dense")
        assert fn is _noop
        with pytest.raises(KeyError, match="no executor registered"):
            engine.get_executor("test-kind", "missing", "dense")
    finally:
        del engine._EXECUTORS[("test-kind", "noop", "dense")]


# ---------------------------------------------------------------------------
# facades are the engine (bitwise), features side included
# ---------------------------------------------------------------------------
def test_from_features_facade_is_plan_execute():
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.normal(size=(18, 4)), jnp.float32)
    for method in ("fused", "kernel", "triplet"):
        C = np.asarray(pald.from_features(X, method=method, block=8,
                                          block_z=8 if method != "triplet"
                                          else None))
        p = pald.plan(X, kind="features", method=method, block=8,
                      block_z=8 if method != "triplet" else None)
        np.testing.assert_array_equal(C, np.asarray(p.execute(X)))


# ---------------------------------------------------------------------------
# plan_local: the distributed shard-body consumer
# ---------------------------------------------------------------------------
def test_plan_local_resolves_tiles_and_forwards():
    lp = engine.plan_local(64, impl="jnp", ties="drop")
    assert lp.block >= 1 and lp.block_z >= 1 and lp.impl == "jnp"
    D = _D(16)
    U = np.asarray(lp.focus_general(D, D, D))
    from repro.kernels import ops as kops
    np.testing.assert_array_equal(
        U, np.asarray(kops.focus_general(D, D, D, impl="jnp",
                                         block=lp.block, block_z=lp.block_z)))
    from repro.kernels.ref import weights_ref
    W = weights_ref(jnp.asarray(U))
    C = np.asarray(lp.cohesion_general(D, D, D, W))
    np.testing.assert_array_equal(
        C, np.asarray(kops.cohesion_general(D, D, D, W, impl="jnp",
                                            block=lp.block,
                                            block_z=lp.block_z)))


# ---------------------------------------------------------------------------
# on_error: the guarded-execution knob at the plan layer (ISSUE 6)
# ---------------------------------------------------------------------------
def test_on_error_knob_is_validated_at_plan_time():
    D = _D()
    for bad in ("retry", "ignore", "", None, 3):
        with pytest.raises((ValueError, TypeError),
                           match="unknown on_error|expected one of"):
            pald.plan(D, on_error=bad)
    with pytest.raises(ValueError, match="'raise', 'fallback'"):
        engine.plan_local(32, on_error="never")


def test_on_error_threads_through_every_facade():
    D = _D()
    X = jnp.asarray(np.random.default_rng(0).normal(size=(12, 3)),
                    jnp.float32)
    assert pald.plan(D, on_error="fallback").on_error == "fallback"
    assert engine.plan_local(32, on_error="fallback").on_error == "fallback"
    # facade one-shots accept it too (they build the plan internally)
    np.testing.assert_array_equal(
        np.asarray(pald.cohesion(D, on_error="fallback")),
        np.asarray(pald.cohesion(D)))
    np.testing.assert_array_equal(
        np.asarray(pald.from_features(X, on_error="fallback")),
        np.asarray(pald.from_features(X)))


def test_strict_mode_propagates_the_original_error_object():
    from repro.testing import faults
    D = _D()
    p = pald.plan(D, method="kernel")  # on_error="raise" is the default
    boom = ValueError("lowering exploded")
    with faults.failing("engine.execute", exc=lambda: boom):
        with pytest.raises(ValueError) as ei:
            p.execute(D)
    assert ei.value is boom  # untouched: no wrapping, no chain walk
    assert p.explain()["degradations"] == []
    faults.reset()


def test_fallback_exhausted_message_names_cell_and_chain():
    """The terminal error is the debugging surface: it must carry the
    failing cell, the primary cause, and every step that was attempted."""
    from repro.core import resilience
    from repro.testing import faults
    D = _D(17)
    p = pald.plan(D, method="kernel", on_error="fallback")
    with faults.failing(""):  # every site: nothing can rescue it
        with pytest.raises(resilience.FallbackExhausted) as ei:
            p.execute(D)
    msg = str(ei.value)
    for frag in ("every fallback failed for cell",
                 "('distance', 'kernel', 'dense')",
                 "primary raised RuntimeError",
                 "degradation chain attempted",
                 "reference"):
        assert frag in msg, (frag, msg)
    assert isinstance(ei.value.__cause__, RuntimeError)
    faults.reset()


def test_oom_retry_floor_is_recorded_before_degrading():
    """An OOM that persists at batch=1 must say so (the "oom-floor" event)
    rather than looping forever or reporting a generic failure."""
    from repro.testing import faults
    D = _D(12)
    Db = jnp.stack([D, D, D])
    p = pald.plan(D, method="kernel", batch=2, on_error="fallback")
    with faults.simulate_oom():  # every batch size "fails to fit"
        C = p.execute(Db)
    causes = [e["cause"] for e in p.explain()["degradations"]]
    assert "oom-floor" in causes
    np.testing.assert_allclose(np.asarray(C), np.asarray(p.execute(Db)),
                               rtol=1e-5, atol=1e-6)
    faults.reset()
