"""jit'd wrappers around the PaLD Pallas kernels.

On TPU the kernels lower to Mosaic; on CPU (this container) either
``interpret=True`` Pallas execution (bit-faithful to the kernel body, used by
tests) or a vectorized jnp fallback with identical semantics (used for speed
in distributed CPU runs) is selected via ``impl=``.

The *general* (rectangular) forms are the primitives that both the sequential
square algorithm and the shard_map distributed algorithms call per device:

    focus_general(DXZ, DYZ, DXY)        -> U (mx, my)
    cohesion_general(DXZ, DYZ, DXY, W)  -> C (mx, mz)

The square sequential forms additionally support ``schedule="tri"`` — the
upper-triangular block schedules (pald_focus_tri / pald_cohesion_tri,
DESIGN.md §4.3) that halve the block-pair visits of both passes.

Block sizes accept ``"auto"``: resolved through the persistent autotuner
cache (``repro.tuning``), falling back to size-aware defaults on a miss.
Dims that don't divide by the chosen tile are padded up to the next tile
multiple (+inf distances / zero weights, exact by construction) instead of
silently degrading to tiny divisor blocks.

Every entry point takes ``ties`` — a mode string, a registered weight
functional name, or a ``WeightFunctional`` instance (``core/weights.py``);
all impls of one functional agree entry-wise, on tied input included.  The
rectangular ``cohesion_general`` form needs the caller to supply the
global-index tiebreak of ``needs_index_tiebreak`` functionals either as an
explicit ``xwins`` array (distributed callers own traced offsets) or as
static ``xw_offsets`` it derives per tile; the square and fused forms
derive it themselves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.resilience import fault_point
from repro.core.weights import (DEFAULT_TIES, focus_weight, index_xwins,
                                resolve_weight, support_weight)
from repro.tuning import autotune as _tuner

from .pald_cohesion import cohesion_general_pallas, cohesion_pallas  # noqa: F401
from .pald_cohesion_tri import cohesion_tri_pallas  # noqa: F401
from .pald_focus import focus_general_pallas, focus_pallas  # noqa: F401
from .pald_focus_tri import focus_tri_pallas  # noqa: F401
from .pald_fused import cohesion_fused_pallas, focus_fused_pallas  # noqa: F401
from .ref import weights_ref

__all__ = [
    "pald",
    "pald_tri",
    "pald_fused",
    "pald_knn",
    "knn_values",
    "topk_select",
    "select_cohere",
    "focus",
    "cohesion_from_weights",
    "focus_general",
    "cohesion_general",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _default_impl() -> str:
    return "pallas" if on_tpu() else "jnp"


def _pick_block(m: int, want: int) -> int:
    """Largest divisor of m that is <= want (block shapes must tile exactly)."""
    b = min(want, m)
    while m % b:
        b -= 1
    return b


def _block_and_pad(m: int, want: int) -> tuple[int, int]:
    """Tile size and padded extent for one dim.

    Shrinking to a divisor of m is fine when the divisor stays reasonable,
    but for prime-ish m it collapses to block=1 — a catastrophic grid (m^2
    steps where there should be (m/want)^2).  In that case pad m up to the
    next multiple of ``want`` and keep the requested tile.
    """
    want = max(min(want, m), 1)
    b = _pick_block(m, want)
    if b == m or b >= max(want // 2, 8):
        return b, m
    return want, -(-m // want) * want


def _pad2(a: jnp.ndarray, mr: int, mc: int, value: float) -> jnp.ndarray:
    r, c = a.shape
    if (r, c) == (mr, mc):
        return a
    return jnp.pad(a, ((0, mr - r), (0, mc - c)), constant_values=value)


def _resolve_blocks(n: int, pass_: str, block, block_z, impl: str,
                    ties=DEFAULT_TIES) -> tuple[int, int]:
    """Turn "auto" block requests into concrete tiles via the tuning cache.

    The weight functional joins the cache key for non-default choices —
    the tile bodies differ (extra equality masks / tiebreak input /
    transcendentals), so their optima may too (``:t-``/``:w-`` key parts).
    """
    if block == "auto" or block_z == "auto":
        rb, rbz = _tuner.resolve_blocks(n, pass_, impl=impl, ties=ties)
        block = rb if block == "auto" else block
        block_z = rbz if block_z == "auto" else block_z
    return int(block), int(block_z)


# --------------------------------------------------------------------------
# jnp fallback with identical semantics to the kernels (z/y-chunked).
# --------------------------------------------------------------------------
# The fallback materializes an (mx, my, chunk) comparison cube per step; at
# production block sizes (6400x6400 on the 2-D distributed schedule) a fixed
# 512-chunk is a 20 GiB buffer.  Cap the bool cube at 512 MiB instead (its
# f32-cast sibling in the cohesion einsum is then <= 2 GiB) — the chunk
# adapts down as blocks grow (PaLD §Perf iteration).
_CUBE_BUDGET = 512 << 20


def _adaptive_chunk(mx: int, my: int, mz: int, want: int) -> int:
    cap = max(_CUBE_BUDGET // max(mx * my, 1), 8)
    return _pick_block(mz, min(want, cap))


@functools.partial(jax.jit, static_argnames=("chunk", "ties"))
def _focus_general_jnp(DXZ, DYZ, DXY, *, chunk: int = 512,
                       ties: str = DEFAULT_TIES):
    mx, mz = DXZ.shape
    c = _adaptive_chunk(mx, DYZ.shape[0], mz, chunk)

    def body(acc, blks):
        dxz, dyz = blks  # (mx, c), (my, c)
        m = focus_weight(dxz[:, None, :], dyz[None, :, :], DXY[:, :, None], ties)
        return acc + jnp.sum(m, axis=-1, dtype=jnp.float32), None

    xs = (
        DXZ.reshape(mx, mz // c, c).transpose(1, 0, 2),
        DYZ.reshape(DYZ.shape[0], mz // c, c).transpose(1, 0, 2),
    )
    U, _ = jax.lax.scan(body, jnp.zeros(DXY.shape, jnp.float32), xs)
    return U


@functools.partial(jax.jit, static_argnames=("chunk", "ties", "xw_offsets"))
def _cohesion_general_jnp(DXZ, DYZ, DXY, W, XW=None, *, chunk: int = 128,
                          ties=DEFAULT_TIES, xw_offsets=None):
    wfun = resolve_weight(ties)
    my = DYZ.shape[0]
    mx, mz = DXZ.shape
    c = _adaptive_chunk(mx, mz, my, chunk)

    def chunked(A):  # (mx, my) -> per-scan-step (mx, c) slabs
        return A.reshape(A.shape[0], my // c, c).transpose(1, 0, 2)

    def body(acc, blks):
        dyz, dxy, w, xw, yoff = blks  # (c, mz), (mx, c), (mx, c), (mx, c)|-, ()
        own = None
        if wfun.needs_index_tiebreak:
            if xw_offsets is not None:
                # derive the (mx, c) tiebreak chunk from static global
                # offsets — the square case never materializes it whole
                own = index_xwins(xw_offsets[0], mx,
                                  xw_offsets[1] + yoff, c)[:, :, None]
            else:
                own = xw[:, :, None]
        g = support_weight(DXZ[:, None, :], dyz[None, :, :], dxy[:, :, None],
                           wfun, own)
        return acc + jnp.einsum("xyz,xy->xz", g, w), None

    if wfun.needs_index_tiebreak and xw_offsets is None:
        if XW is None:
            raise ValueError(f"weight {wfun.name!r} needs XW "
                             "(global-index tiebreak)")
        xw_chunks = chunked(XW)
    else:
        # dummy zero-size leaf keeps the scan structure mode-independent
        xw_chunks = jnp.zeros((my // c, mx, 0), jnp.bool_)
    xs = (DYZ.reshape(my // c, c, -1), chunked(DXY), chunked(W), xw_chunks,
          jnp.arange(my // c, dtype=jnp.int32) * c)
    C, _ = jax.lax.scan(body, jnp.zeros((DXZ.shape[0], DXZ.shape[1]), jnp.float32), xs)
    return C


# --------------------------------------------------------------------------
# jnp fallbacks for the upper-triangular block schedules (square case).
# Same tile bodies as the tri kernels: both role updates go through the
# shared tie predicate, with the block coordinates providing the
# ties='ignore' global-index tiebreak.
# --------------------------------------------------------------------------
def _tri_pairs(nb: int):
    import numpy as np
    xs, ys = np.triu_indices(nb)
    return jnp.asarray(xs, jnp.int32), jnp.asarray(ys, jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "ties"))
def _focus_tri_jnp(D, *, block: int = 128, ties=DEFAULT_TIES):
    n = D.shape[0]
    nb = n // block
    xs, ys = _tri_pairs(nb)

    def body(i, U):
        xb, yb = xs[i], ys[i]
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))
        Dxy = jax.lax.dynamic_slice_in_dim(Dx, yb * block, block, axis=1)
        m = focus_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None], ties)
        blk = jnp.sum(m, axis=-1, dtype=jnp.float32)
        U = jax.lax.dynamic_update_slice(U, blk, (xb * block, yb * block))
        return jax.lax.dynamic_update_slice(U, blk.T, (yb * block, xb * block))

    npairs = int(xs.shape[0])
    return jax.lax.fori_loop(0, npairs, body, jnp.zeros((n, n), jnp.float32))


@functools.partial(jax.jit, static_argnames=("block", "ties"))
def _cohesion_tri_jnp(D, W, *, block: int = 128, ties=DEFAULT_TIES):
    """Both role updates per upper-triangular block pair.

    The y-role is expressed in the same row-major orientation as the x-role
    (roles swapped through the symmetry of D and W), so both einsums reduce
    over the middle axis — the matmul-friendly layout XLA lowers best.  Both
    roles evaluate the shared tie predicate in the requested mode (the
    pre-PR3 complement trick hard-coded ties->y off-diagonal and strict
    comparisons on the diagonal, matching neither reference on tied input).
    Diagonal blocks skip the y-role computation entirely (lax.cond): the
    one-sided x-role already covers both orders of every in-block pair.
    """
    wfun = resolve_weight(ties)
    n = D.shape[0]
    nb = n // block
    xs, ys = _tri_pairs(nb)

    def body(i, C):
        xb, yb = xs[i], ys[i]
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))
        Dxy = jax.lax.dynamic_slice_in_dim(Dx, yb * block, block, axis=1)
        Wxy = jax.lax.dynamic_slice(W, (xb * block, yb * block), (block, block))
        xw = yw = None
        if wfun.needs_index_tiebreak:
            xw = index_xwins(xb * block, block, yb * block, block)[:, :, None]
            yw = index_xwins(yb * block, block, xb * block, block)[:, :, None]
        gx = support_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None],
                            ties, xw)
        add_x = jnp.einsum("xyz,xy->xz", gx, Wxy)

        def y_role(_):
            gy = support_weight(Dy[:, None, :], Dx[None, :, :],
                                Dxy.T[:, :, None], ties, yw)
            return jnp.einsum("yxz,yx->yz", gy, Wxy.T)

        add_y = jax.lax.cond(
            xb == yb, lambda _: jnp.zeros((block, n), jnp.float32), y_role, None
        )
        rx = jax.lax.dynamic_slice(C, (xb * block, 0), (block, n))
        C = jax.lax.dynamic_update_slice(C, rx + add_x, (xb * block, 0))
        ry = jax.lax.dynamic_slice(C, (yb * block, 0), (block, n))
        return jax.lax.dynamic_update_slice(C, ry + add_y, (yb * block, 0))

    npairs = int(xs.shape[0])
    return jax.lax.fori_loop(0, npairs, body, jnp.zeros((n, n), jnp.float32))


def _pad_square_tri(D, W, q: int):
    """Pad square inputs to a multiple of the tile quantum q (inf distances,
    zero weights: padded points never contribute to real entries)."""
    n = D.shape[0]
    m = -(-n // q) * q
    if m == n:
        return D, W, n
    Dp = _pad2(D.astype(jnp.float32), m, m, jnp.inf)
    Dp = Dp.at[jnp.arange(n, m), jnp.arange(n, m)].set(0.0)
    Wp = None if W is None else _pad2(W.astype(jnp.float32), m, m, 0.0)
    return Dp, Wp, n


# --------------------------------------------------------------------------
# jnp fallback for the fused features->cohesion pipeline.  Per (xb, yb) block
# pair, the (block, m) distance row slabs are recomputed from (block, d)
# feature slices — O(d/block) relative overhead — so the full (m, m) D matrix
# never exists as a value; only (block, m) slabs are live inside the loops.
# --------------------------------------------------------------------------
def _dist_slab(X, off, block, metric, n_valid):
    """Masked (block, m) distance rows starting at global row ``off``."""
    from repro.core.features import masked_dist_tile

    Xa = jax.lax.dynamic_slice(X, (off, 0), (block, X.shape[1]))
    return masked_dist_tile(Xa, X, metric, off, 0, n_valid)


def _fused_z_chunk(m: int, block: int, block_z: int) -> int:
    """z-chunk of the fused comparison cubes: the requested block_z, shrunk
    to the same 512 MiB cube budget the general jnp fallbacks honor, and to
    a divisor of m (slabs tile exactly)."""
    cap = max(_CUBE_BUDGET // max(block * block, 1), 8)
    return _pick_block(m, max(min(block_z, cap), 1))


@functools.partial(jax.jit,
                   static_argnames=("metric", "block", "block_z", "n_valid",
                                    "ties"))
def _focus_fused_jnp(X, *, metric: str, block: int, block_z: int, n_valid: int,
                     ties=DEFAULT_TIES):
    m = X.shape[0]
    nb = m // block
    cz = _fused_z_chunk(m, block, block_z)

    def outer(xb, U):
        Dx = _dist_slab(X, xb * block, block, metric, n_valid)

        def inner(yb, U):
            Dy = _dist_slab(X, yb * block, block, metric, n_valid)
            Dxy = jax.lax.dynamic_slice(Dx, (0, yb * block), (block, block))

            def zstep(zb, acc):
                dxc = jax.lax.dynamic_slice(Dx, (0, zb * cz), (block, cz))
                dyc = jax.lax.dynamic_slice(Dy, (0, zb * cz), (block, cz))
                msk = focus_weight(dxc[:, None, :], dyc[None, :, :],
                                   Dxy[:, :, None], ties)
                return acc + jnp.sum(msk, axis=-1, dtype=jnp.float32)

            blk = jax.lax.fori_loop(0, m // cz, zstep,
                                    jnp.zeros((block, block), jnp.float32))
            return jax.lax.dynamic_update_slice(U, blk, (xb * block, yb * block))

        return jax.lax.fori_loop(0, nb, inner, U)

    return jax.lax.fori_loop(0, nb, outer, jnp.zeros((m, m), jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("metric", "block", "block_z", "n_valid",
                                    "ties"))
def _cohesion_fused_jnp(X, W, *, metric: str, block: int, block_z: int,
                        n_valid: int, ties=DEFAULT_TIES):
    wfun = resolve_weight(ties)
    m = X.shape[0]
    nb = m // block
    cz = _fused_z_chunk(m, block, block_z)

    def outer(xb, C):
        Dx = _dist_slab(X, xb * block, block, metric, n_valid)

        def inner(yb, acc):
            Dy = _dist_slab(X, yb * block, block, metric, n_valid)
            Dxy = jax.lax.dynamic_slice(Dx, (0, yb * block), (block, block))
            Wxy = jax.lax.dynamic_slice(W, (xb * block, yb * block), (block, block))
            xw = None
            if wfun.needs_index_tiebreak:  # every ordered block pair visited
                xw = index_xwins(xb * block, block, yb * block, block)[:, :, None]

            def zstep(zb, acc):
                dxc = jax.lax.dynamic_slice(Dx, (0, zb * cz), (block, cz))
                dyc = jax.lax.dynamic_slice(Dy, (0, zb * cz), (block, cz))
                g = support_weight(dxc[:, None, :], dyc[None, :, :],
                                   Dxy[:, :, None], ties, xw)
                addc = jnp.einsum("xyz,xy->xz", g, Wxy)
                acc_c = jax.lax.dynamic_slice(acc, (0, zb * cz), (block, cz))
                return jax.lax.dynamic_update_slice(acc, acc_c + addc, (0, zb * cz))

            return jax.lax.fori_loop(0, m // cz, zstep, acc)

        add = jax.lax.fori_loop(0, nb, inner, jnp.zeros((block, m), jnp.float32))
        row = jax.lax.dynamic_slice(C, (xb * block, 0), (block, m))
        return jax.lax.dynamic_update_slice(C, row + add, (xb * block, 0))

    return jax.lax.fori_loop(0, nb, outer, jnp.zeros((m, m), jnp.float32))


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------
def focus_general(DXZ, DYZ, DXY, *, block=128, block_z=512,
                  impl: str | None = None, ties=DEFAULT_TIES):
    ties = resolve_weight(ties)
    impl = impl or _default_impl()
    fault_point("ops.focus_general", impl=impl, ties=ties.name)
    block, block_z = _resolve_blocks(max(DXZ.shape), "focus", block, block_z,
                                     impl, ties)
    if impl == "jnp":
        return _focus_general_jnp(DXZ, DYZ, DXY, chunk=block_z, ties=ties)
    (mx, mz), my = DXZ.shape, DYZ.shape[0]
    bx, mxp = _block_and_pad(mx, block)
    by, myp = _block_and_pad(my, block)
    bz, mzp = _block_and_pad(mz, block_z)
    U = focus_general_pallas(
        _pad2(DXZ, mxp, mzp, jnp.inf),
        _pad2(DYZ, myp, mzp, jnp.inf),
        _pad2(DXY, mxp, myp, jnp.inf),
        block_x=bx, block_y=by, block_z=bz, interpret=impl == "interpret",
        ties=ties,
    )
    return U[:mx, :my]


def cohesion_general(DXZ, DYZ, DXY, W, *, block=128, block_z=512,
                     impl: str | None = None, ties=DEFAULT_TIES,
                     xwins=None, xw_offsets=None):
    """For ``needs_index_tiebreak`` functionals (``ties='ignore'``) the
    rectangular form needs the global-index tiebreak — either ``xwins``
    (mx, my) bool, "global index of x > global index of y", for
    distributed callers whose row identities are data (traced offsets);
    or static ``xw_offsets`` = (row_off, col_off) global offsets, from
    which the tiebreak is derived per tile/chunk and never materialized
    whole (the square sequential case passes (0, 0))."""
    ties = resolve_weight(ties)
    impl = impl or _default_impl()
    fault_point("ops.cohesion_general", impl=impl, ties=ties.name)
    block, block_z = _resolve_blocks(max(DXZ.shape), "cohesion", block, block_z,
                                     impl, ties)
    if ties.needs_index_tiebreak and xwins is None and xw_offsets is None:
        raise ValueError(f"weight {ties.name!r} needs xwins or xw_offsets "
                         "(global-index tiebreak)")
    if impl == "jnp":
        XW = offs = None
        if ties.needs_index_tiebreak:
            XW, offs = xwins, (None if xwins is not None else tuple(xw_offsets))
        return _cohesion_general_jnp(DXZ, DYZ, DXY, W, XW, chunk=block,
                                     ties=ties, xw_offsets=offs)
    (mx, mz), my = DXZ.shape, DYZ.shape[0]
    bx, mxp = _block_and_pad(mx, block)
    by, myp = _block_and_pad(my, block)
    bz, mzp = _block_and_pad(mz, block_z)
    XW = offs = None
    if ties.needs_index_tiebreak:
        if xwins is not None:
            # pad with 0 ("x does not win"): padded pairs carry zero weight
            XW = _pad2(xwins.astype(jnp.float32), mxp, myp, 0.0)
        else:
            # per-tile in-kernel derivation from the static global offsets
            offs = (int(xw_offsets[0]), int(xw_offsets[1]))
    C = cohesion_general_pallas(
        _pad2(DXZ, mxp, mzp, jnp.inf),
        _pad2(DYZ, myp, mzp, jnp.inf),
        _pad2(DXY, mxp, myp, jnp.inf),
        _pad2(W, mxp, myp, 0.0),
        XW,
        block_x=bx, block_z=bz, block_y=by, interpret=impl == "interpret",
        ties=ties, xw_offsets=offs,
    )
    return C[:mx, :mz]


def focus(D, *, block=128, block_z=512, impl: str | None = None,
          schedule: str = "dense", ties=DEFAULT_TIES):
    """schedule='tri' uses the upper-triangular scalar-prefetch kernel
    (pald_focus_tri): ~half the comparisons of the dense grid, same
    result.  Only meaningful for the square (sequential) case."""
    ties = resolve_weight(ties)
    if schedule == "tri":
        impl = impl or ("pallas" if on_tpu() else "jnp")
        n = D.shape[0]
        block, block_z = _resolve_blocks(n, "focus_tri", block, block_z, impl,
                                         ties)
        block, block_z = min(block, n), min(block_z, n)
        if impl == "jnp":
            Dp, _, n0 = _pad_square_tri(D, None, block)
            return _focus_tri_jnp(Dp, block=block, ties=ties)[:n0, :n0]
        # pad to the largest tile, then shrink tiles to divisors of the
        # padded size (keeps the quantum bounded — never an lcm blow-up)
        Dp, _, n0 = _pad_square_tri(D, None, max(block, block_z))
        m = Dp.shape[0]
        block, block_z = _pick_block(m, block), _pick_block(m, block_z)
        U = focus_tri_pallas(
            Dp, block=block, block_z=block_z, interpret=impl == "interpret",
            ties=ties,
        )
        return U[:n0, :n0]
    return focus_general(D, D, D, block=block, block_z=block_z, impl=impl,
                         ties=ties)


def cohesion_from_weights(D, W, *, block=128, block_z=512, impl: str | None = None,
                          schedule: str = "dense", ties=DEFAULT_TIES):
    """Pass 2 from precomputed reciprocal weights W = 1/U.

    schedule='tri' enumerates only the upper-triangular block pairs and
    applies both role updates per visit (pald_cohesion_tri).  The square
    case derives the index tiebreak per tile itself (``xw_offsets=(0, 0)``
    — the dense (n, n) tiebreak is never materialized)."""
    ties = resolve_weight(ties)
    if schedule == "tri":
        impl = impl or ("pallas" if on_tpu() else "jnp")
        n = D.shape[0]
        block, block_z = _resolve_blocks(n, "cohesion_tri", block, block_z,
                                         impl, ties)
        block, block_z = min(block, n), min(block_z, n)
        if impl == "jnp":
            Dp, Wp, n0 = _pad_square_tri(D, W, block)
            return _cohesion_tri_jnp(Dp, Wp, block=block, ties=ties)[:n0, :n0]
        Dp, Wp, n0 = _pad_square_tri(D, W, max(block, block_z))
        m = Dp.shape[0]
        block, block_z = _pick_block(m, block), _pick_block(m, block_z)
        C = cohesion_tri_pallas(
            Dp, Wp, block=block, block_z=block_z, interpret=impl == "interpret",
            ties=ties,
        )
        return C[:n0, :n0]
    offs = (0, 0) if ties.needs_index_tiebreak else None
    return cohesion_general(D, D, D, W, block=block, block_z=block_z, impl=impl,
                            ties=ties, xw_offsets=offs)


def pald(
    D,
    *,
    block=128,
    block_z=512,
    normalize: bool = False,
    n_valid=None,
    impl: str | None = None,
    schedule: str = "dense",
    ties=DEFAULT_TIES,
):
    """Full PaLD via the kernel pipeline (inputs padded internally as needed).

    impl: 'pallas' (TPU), 'interpret' (CPU bit-faithful kernel execution),
    'jnp' (vectorized fallback), or None for backend default.
    schedule: 'dense' runs the full rectangular grids; 'tri' dispatches to
    the fused upper-triangular pipeline (``pald_tri``).
    ties: weight functional (name or instance) shared by both passes
    (core/weights.py).
    """
    if schedule == "tri":
        return pald_tri(D, block=block, block_z=block_z, normalize=normalize,
                        n_valid=n_valid, impl=impl, ties=ties)
    impl = impl or ("pallas" if on_tpu() else "interpret")
    U = focus(D, block=block, block_z=block_z, impl=impl, ties=ties)
    W = weights_ref(U, n_valid)
    C = cohesion_from_weights(D, W, block=block, block_z=block_z, impl=impl,
                              ties=ties)
    if normalize:
        C = C / (D.shape[0] - 1)
    return C


def pald_fused(
    X,
    *,
    metric: str = "euclidean",
    block=128,
    block_z=512,
    normalize: bool = False,
    impl: str | None = None,
    ties=DEFAULT_TIES,
):
    """Fused features→cohesion pipeline: X (n, d) -> C (n, n).

    Distance tiles are computed on the fly from (block, d) feature tiles —
    inside the Pallas kernels on TPU (``pald_fused.py``), inside the block
    loops of the jnp fallback on CPU — so the full (n, n) distance matrix is
    never materialized.  Feature rows are zero-padded to the tile quantum;
    the +inf/zero-diagonal padding contract is re-imposed per tile from the
    static ``n_valid``.

    ``block="auto"`` resolves tiles through the tuning cache under the
    ``pald_fused`` pass, keyed by (n, d).
    """
    from repro.core.features import pad_features

    ties = resolve_weight(ties)
    impl = impl or ("pallas" if on_tpu() else "jnp")
    fault_point("ops.pald_fused", impl=impl, ties=ties.name)
    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    block, block_z, _ = _tuner.resolve_fused_tiles(n, d, block, block_z,
                                                   impl=impl, ties=ties)
    if impl == "jnp":
        Xp, n0 = pad_features(X, block)
        U = _focus_fused_jnp(Xp, metric=metric, block=block, block_z=block_z,
                             n_valid=n0, ties=ties)
        W = weights_ref(U, n0 if Xp.shape[0] != n0 else None)
        C = _cohesion_fused_jnp(Xp, W, metric=metric, block=block,
                                block_z=block_z, n_valid=n0, ties=ties)
    else:
        from .pald_fused import cohesion_fused_pallas, focus_fused_pallas

        Xp, n0 = pad_features(X, max(block, block_z))
        m = Xp.shape[0]
        block, block_z = _pick_block(m, block), _pick_block(m, block_z)
        if impl == "pallas" and d % 128:
            # zero feature columns are exact no-ops for every metric; pad d
            # to the lane quantum so Mosaic gets aligned (block, d) tiles
            Xp = jnp.pad(Xp, ((0, 0), (0, 128 - d % 128)))
        interp = impl == "interpret"
        U = focus_fused_pallas(Xp, metric=metric, n_valid=n0, block=block,
                               block_z=block_z, interpret=interp, ties=ties)
        W = weights_ref(U, n0 if m != n0 else None)
        C = cohesion_fused_pallas(Xp, W, metric=metric, n_valid=n0,
                                  block=block, block_z=block_z,
                                  interpret=interp, ties=ties)
    C = C[:n, :n]
    if normalize:
        C = C / max(n - 1, 1)
    return C


def pald_tri(
    D,
    *,
    block=128,
    block_z=512,
    normalize: bool = False,
    n_valid=None,
    impl: str | None = None,
    ties=DEFAULT_TIES,
):
    """Fused tri-schedule pipeline: tri-focus -> precomputed-reciprocal
    weights -> tri-cohesion.  Both passes visit only the nb(nb+1)/2
    upper-triangular block pairs (paper Algorithm 2 at block granularity,
    DESIGN.md §4.3); padding to the tile multiple happens once here.
    """
    ties = resolve_weight(ties)
    impl = impl or ("pallas" if on_tpu() else "interpret")
    fault_point("ops.pald_tri", impl=impl, ties=ties.name)
    n_in = D.shape[0]
    bf, bzf = _resolve_blocks(n_in, "focus_tri", block, block_z, impl, ties)
    bc, bzc = _resolve_blocks(n_in, "cohesion_tri", block, block_z, impl, ties)
    bf, bzf = min(bf, n_in), min(bzf, n_in)
    bc, bzc = min(bc, n_in), min(bzc, n_in)
    # one pipeline-level pad to the largest requested tile, then shrink each
    # tile to a divisor of the padded size (bounded quantum, no lcm blow-up)
    tiles = (bf, bc) if impl == "jnp" else (bf, bc, bzf, bzc)
    Dp, _, _ = _pad_square_tri(D, None, max(tiles))
    m = Dp.shape[0]
    bf, bc = _pick_block(m, bf), _pick_block(m, bc)
    bzf, bzc = _pick_block(m, bzf), _pick_block(m, bzc)
    nv = n_valid if n_valid is not None else (n_in if Dp.shape[0] != n_in else None)
    if impl == "jnp":
        U = _focus_tri_jnp(Dp, block=bf, ties=ties)
        W = weights_ref(U, nv)
        C = _cohesion_tri_jnp(Dp, W, block=bc, ties=ties)
    else:
        interp = impl == "interpret"
        U = focus_tri_pallas(Dp, block=bf, block_z=bzf, interpret=interp,
                             ties=ties)
        W = weights_ref(U, nv)
        C = cohesion_tri_pallas(Dp, W, block=bc, block_z=bzc, interpret=interp,
                                ties=ties)
    C = C[:n_in, :n_in]
    if normalize:
        C = C / (n_in - 1)
    return C


# --------------------------------------------------------------------------
# sparse k-NN pipeline (O(n * k^2) cohesion; core/knn.py has the semantics).
# The jnp fallback streams the gathered (block, k, k) neighbor tiles chunk
# by chunk (O(block * k^2) live); the Pallas path stages the full gathered
# cube in HBM (O(n * k^2)) and lets the kernel iterate (block, k) tiles.
# --------------------------------------------------------------------------
from repro.core import knn as _knn  # noqa: E402


def _gather_tiles(x, idxc, kind: str, metric: str):
    if kind == "distance":
        return _knn.gather_tile_from_distances(x, idxc)
    return _knn.gather_tile_from_features(x, idxc, metric)


@functools.partial(jax.jit,
                   static_argnames=("kind", "metric", "block", "ties"))
def _knn_values_jnp(x, dn_p, idx_p, *, kind: str, metric: str, block: int,
                    ties=DEFAULT_TIES):
    """Blocked-jnp fallback: lax.map over row chunks of the padded graph;
    each chunk gathers its own (block, k, k) tile and runs the shared
    ``knn_values_tile`` body."""
    wfun = resolve_weight(ties)
    m, k = dn_p.shape
    offs = jnp.arange(m // block) * block

    def chunk(off):
        dnc = jax.lax.dynamic_slice(dn_p, (off, 0), (block, k))
        idxc = jax.lax.dynamic_slice(idx_p, (off, 0), (block, k))
        g = _gather_tiles(x, idxc, kind, metric)
        ow = None
        if wfun.needs_index_tiebreak:
            ow = (off + jnp.arange(block))[:, None] > idxc
        return _knn.knn_values_tile(dnc, g, ow, wfun)

    return jax.lax.map(chunk, offs).reshape(m, k + 1)


def knn_values(
    x,
    graph: "_knn.NeighborGraph",
    *,
    kind: str = "distance",
    metric: str = "euclidean",
    block: int | str = "auto",
    impl: str | None = None,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """Sparse (n, k+1) cohesion values for a prebuilt neighbor graph.

    Args:
        x: the gather source the graph was built from — the (n, n)
            distance matrix (``kind="distance"``) or the (n, d) feature
            matrix (``kind="features"``; neighbor-to-neighbor tiles are
            recomputed from features so D never materializes).
        graph: ``core.knn.NeighborGraph`` over the same ``x``.
        block: row-tile size; ``"auto"`` resolves via the tuning cache
            under the ``pald_knn:k<k>`` pass.
        impl: 'pallas' (TPU), 'interpret' (bit-faithful kernel on CPU) or
            'jnp' (vectorized fallback, the CPU speed path); None =
            backend default.
        ties: weight functional (name or instance) shared with every
            other path (``core/weights.py``).

    Returns:
        (n, k+1) float32 values, column 0 = self support, un-normalized.
    """
    ties = resolve_weight(ties)
    impl = impl or _default_impl()
    fault_point("ops.knn_values", impl=impl, ties=ties.name)
    x = jnp.asarray(x, jnp.float32)
    n, k = graph.indices.shape
    if k == 0:  # n == 1 (or an explicit empty graph): no pairs, no support
        return jnp.zeros((n, 1), jnp.float32)
    if block == "auto":
        block, _ = _tuner.resolve_blocks(n, "pald_knn", impl=impl, ties=ties,
                                         k=k)
    block = max(min(int(block), n), 1)
    m = -(-n // block) * block
    dn_p = _pad2(graph.distances.astype(jnp.float32), m, k, jnp.inf)
    idx_p = _pad2(graph.indices, m, k, 0)
    if impl == "jnp":
        vals = _knn_values_jnp(x, dn_p, idx_p, kind=kind, metric=metric,
                               block=block, ties=ties)
        return vals[:n]
    from .pald_knn import knn_values_pallas

    g = _gather_tiles(x, idx_p, kind, metric)          # (m, k, k), real k
    kp = k if impl == "interpret" else -(-k // 128) * 128
    if kp != k:
        # lane-pad the neighbor axis AFTER gathering (a pre-pad gather
        # would stage and recompute a (kp/k)^2-times-larger cube): +inf
        # pair distances, index 0, zero gathered distances — the kernel
        # masks every padded column out of the focus count and pair
        # weights via k_valid
        dn_p = _pad2(dn_p, m, kp, jnp.inf)
        idx_p = _pad2(idx_p, m, kp, 0)
        g = jnp.pad(g, ((0, 0), (0, kp - k), (0, kp - k)))
    vals = knn_values_pallas(dn_p, g, idx_p, block=block, k_valid=k,
                             ties=ties, interpret=impl == "interpret")
    return vals[:n, :k + 1]


def pald_knn(
    x,
    *,
    k: int,
    kind: str = "distance",
    metric: str = "euclidean",
    block: int | str = "auto",
    impl: str | None = None,
    ties=DEFAULT_TIES,
    normalize: bool = False,
    row_chunk: int = 1024,
    graph: "_knn.NeighborGraph | None" = None,
) -> tuple["_knn.NeighborGraph", jnp.ndarray]:
    """Full sparse k-NN PaLD: neighbor selection + sparse cohesion values.

    Args:
        x: (n, n) distances (``kind="distance"``) or (n, d) features
            (``kind="features"`` — D is never materialized: selection is
            row-chunked and cohesion tiles are recomputed from features).
        k: neighborhood size; clamped to n-1.  NOTE: unlike the engine
            executor behind ``pald.cohesion(method="knn")``, this entry
            point always runs the sparse machinery, even at k = n-1 — the
            executor short-circuits that case to the exact dense path.
        graph: optional prebuilt NeighborGraph (skips selection — useful
            when scoring multiple tie modes on one neighborhood).
        normalize: divide values by (n-1), matching the dense pipelines.
        (Other knobs: see ``knn_values``.)

    Returns:
        (graph, values): the NeighborGraph used and the (n, k+1) sparse
        cohesion values (column 0 = self).  ``core.knn.scatter_dense``
        expands them to the dense (n, n) C; ``core.knn.communities``
        consumes them directly.

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1., 4.], [1., 0., 2.], [4., 2., 0.]])
        >>> g, vals = pald_knn(D, k=1)
        >>> vals.shape
        (3, 2)
    """
    ties = resolve_weight(ties)
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    k = min(int(k), max(n - 1, 0))
    if graph is None:
        if kind == "distance":
            graph = _knn.knn_from_distances(x, k)
        elif kind == "features":
            graph = _knn.knn_from_features(x, k, metric=metric,
                                           row_chunk=row_chunk)
        else:
            raise ValueError(f"unknown kind {kind!r} "
                             "(expected 'distance' or 'features')")
    vals = knn_values(x, graph, kind=kind, metric=metric, block=block,
                      impl=impl, ties=ties)
    if normalize:
        vals = vals / max(n - 1, 1)
    return graph, vals


# --------------------------------------------------------------------------
# streaming neighbor selection (ROADMAP item 3).  Three impl families, all
# bitwise-identical to core.knn._top_k_rows on the masked distances:
#
#   pallas / interpret  kernels/pald_topk.py — (block, d) feature tiles,
#                       in-register distance tiles folded into a running
#                       (block, k) best-list by composite-key bitonic merge;
#                       neither D nor full score rows ever hit HBM.
#   jnp                 blocked-jnp fallback: one jit, lax.map over row
#                       slabs.  Strategy per slab from ``tile``:
#                       tile >= n  -> direct full-width stable lax.top_k;
#                       tile <  n  -> exact tile-min prefilter (per-tile
#                       minima over the EXACT distances pick k candidate
#                       tiles per row, the final top-k runs over the k*tile
#                       gathered columns).  Exactness: if element e were
#                       wrongly excluded, >= k tiles beat e's tile — tiles
#                       earlier in index order beat it tie-safely (their
#                       candidates have smaller indices), later tiles by
#                       strictly smaller minima — so the true top-k always
#                       survives the gather, tie-break included.  The proof
#                       needs the sqrt'd (exact) distances: per-tile minima
#                       over d^2 can invert across the sqrt rounding.
#   chunked             terminal degradation rung: unfused per-slab
#                       dist_tile -> host sync -> row-chunked lax.top_k,
#                       no fused machinery on the failure path.
#
# ``tile`` ("auto") and the slab size ``block`` resolve via the tuning
# cache pass ``pald_topk:k<k>:d<d>``: the optimum is k- and d-dependent
# (the prefilter amortizes the full-width top_k re-scan, which XLA:CPU
# makes data-dependent — clustered rows branch-predict ~2-3x faster than
# random ones), with the block_z slot of the record holding ``tile``.
# --------------------------------------------------------------------------
from .pald_topk import next_pow2 as _next_pow2  # noqa: E402
from .pald_topk import topk_pallas  # noqa: E402


def _topk_chunk(Xp, off, *, k: int, metric: str, chunk: int, n: int,
                tile: int):
    """One (chunk, n) selection slab -> ((chunk, k) dist, (chunk, k) idx)."""
    from repro.core.features import dist_tile

    X = Xp[:n]
    rows = jax.lax.dynamic_slice(Xp, (off, 0), (chunk, Xp.shape[1]))
    Dr = dist_tile(rows, X, metric)                       # (chunk, n)
    gids = off + jnp.arange(chunk)
    self_ = gids[:, None] == jnp.arange(n)[None, :]
    if tile >= n or tile < 1:                             # direct strategy
        return _knn._top_k_rows(jnp.where(self_, -jnp.inf, -Dr), k)
    Dr = jnp.where(self_, jnp.inf, Dr)
    nt = -(-n // tile)
    Drp = jnp.pad(Dr, ((0, 0), (0, nt * tile - n)),
                  constant_values=jnp.inf)
    M = jnp.min(Drp.reshape(chunk, nt, tile), axis=2)     # (chunk, nt)
    kt = min(k, nt)
    _, tids = jax.lax.top_k(-M, kt)
    # ascending tile ids keep gathered columns in global index order, so
    # the stable top_k below reproduces the lower-index-first tiebreak
    tids = jnp.sort(tids, axis=1)
    cols = (tids[:, :, None] * tile +
            jnp.arange(tile)[None, None, :]).reshape(chunk, kt * tile)
    Dg = jnp.take_along_axis(Drp, cols, axis=1)
    negv, p = jax.lax.top_k(-Dg, k)
    return -negv, jnp.take_along_axis(cols, p, axis=1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "chunk", "n", "tile"))
def _topk_select_jnp(Xp, *, k: int, metric: str, chunk: int, n: int,
                     tile: int):
    offs = jnp.arange(Xp.shape[0] // chunk) * chunk
    return jax.lax.map(
        functools.partial(_topk_chunk, Xp, k=k, metric=metric, chunk=chunk,
                          n=n, tile=tile), offs)          # (nc, chunk, k)


@functools.partial(jax.jit, static_argnames=("metric", "k", "n"))
def _topk_slab_chunked(rows, X, off, *, metric: str, k: int, n: int):
    """One rung slab: dist_tile -> mask -> stable lax.top_k (row-chunked).

    ``off`` is traced (one compilation per slab SHAPE, not per offset)."""
    from repro.core.features import dist_tile

    Dr = dist_tile(rows, X, metric)
    gids = off + jnp.arange(rows.shape[0])
    self_ = gids[:, None] == jnp.arange(n)[None, :]
    return _knn._top_k_rows(jnp.where(self_, -jnp.inf, -Dr), k)


def _topk_select_chunked(X, k: int, *, metric: str, row_chunk: int = 256):
    """Terminal degradation rung: unfused host-driven slabs.

    Each slab is an independent jit (distances -> top_k) synced to host
    before the next starts — no lax.map, no fused program, the smallest
    machinery that can still answer.  Bitwise equals the direct jnp
    strategy (identical per-row ops; chunking never changes a row)."""
    n = X.shape[0]
    out_d, out_i = [], []
    tracing = isinstance(X, jax.core.Tracer)
    for off in range(0, n, row_chunk):
        rows = X[off:off + min(row_chunk, n - off)]
        dv, di = _topk_slab_chunked(rows, X, jnp.int32(off), metric=metric,
                                    k=k, n=n)
        if not tracing:
            jax.block_until_ready(dv)
        out_d.append(dv)
        out_i.append(di)
    return jnp.concatenate(out_d), jnp.concatenate(out_i)


def _knn_from_distances_chunked(D, k: int, *, row_chunk: int = 1024):
    """Row-chunked lax.top_k over a materialized D (distance-kind rung).

    Bitwise equals ``core.knn.knn_from_distances`` — same per-row mask and
    stable top_k, slab at a time instead of one full-matrix call."""
    D = jnp.asarray(D, jnp.float32)
    n = D.shape[0]
    tracing = isinstance(D, jax.core.Tracer)
    out_d, out_i = [], []
    for off in range(0, n, row_chunk):
        rows = D[off:off + min(row_chunk, n - off)]
        gids = off + jnp.arange(rows.shape[0])
        self_ = gids[:, None] == jnp.arange(n)[None, :]
        dv, di = _knn._top_k_rows(jnp.where(self_, -jnp.inf, -rows), k)
        if not tracing:
            jax.block_until_ready(dv)
        out_d.append(dv)
        out_i.append(di)
    return _knn.NeighborGraph(jnp.concatenate(out_i), jnp.concatenate(out_d))


def _resolve_topk_tiles(n: int, d: int, k: int, block, tile,
                        impl: str) -> tuple[int, int]:
    """Turn "auto" selection knobs into (row slab, tile) via the cache."""
    if block == "auto" or tile == "auto":
        rb, rt = _tuner.resolve_blocks(n, "pald_topk", impl=impl, d=d, k=k)
        block = rb if block == "auto" else block
        tile = rt if tile == "auto" else tile
    return max(min(int(block), max(n, 1)), 1), int(tile)


def topk_select(
    X,
    k: int,
    *,
    metric: str = "euclidean",
    impl: str | None = None,
    block: int | str = "auto",
    tile: int | str = "auto",
) -> "_knn.NeighborGraph":
    """Streaming neighbor selection: (n, d) features -> NeighborGraph.

    The selection counterpart of ``knn_values``: one entry point, every
    impl bitwise-identical to ``core.knn._top_k_rows`` on the self-masked
    distances (stable lower-index-first tie-break included).

    Args:
        X: (n, d) feature matrix (cast to float32 once).
        k: neighborhood size, ``0 <= k <= n-1``.
        metric: one of ``features.METRICS``.
        impl: 'pallas' (TPU) / 'interpret' — the streaming Pallas kernel
            (``kernels/pald_topk.py``); 'jnp' — the blocked-jnp fallback
            (direct or tile-min-prefiltered, see module comment);
            'chunked' — the terminal degradation rung (unfused per-slab
            ``lax.top_k`` with host syncs).  None = backend default.
        block: rows per selection slab (the kernel's row tile); "auto"
            resolves via the ``pald_topk:k<k>:d<d>`` tuning-cache pass.
        tile: jnp strategy knob — column tile width of the tile-min
            prefilter; ``tile >= n`` means direct full-width top_k.  For
            the Pallas impls this is the candidate tile ``block_z``
            (rounded to a power of two).  "auto" resolves with ``block``.

    Returns:
        ``core.knn.NeighborGraph`` — indices/distances (n, k).

    Raises:
        ValueError: unknown metric/impl, or ``k > n-1``.
    """
    impl = impl or _default_impl()
    if impl not in ("pallas", "interpret", "jnp", "chunked"):
        raise ValueError(
            f"unknown impl {impl!r} (expected 'pallas', 'interpret', "
            "'jnp' or 'chunked')")
    fault_point("ops.topk_select", impl=impl, metric=metric)
    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    if k > max(n - 1, 0):
        raise ValueError(f"k={k} exceeds the n-1={n - 1} available neighbors")
    if k <= 0:
        return _knn.NeighborGraph(jnp.zeros((n, 0), jnp.int32),
                                  jnp.zeros((n, 0), jnp.float32))
    block, tile = _resolve_topk_tiles(n, d, k, block, tile, impl)
    if impl == "chunked":
        dv, di = _topk_select_chunked(X, k, metric=metric, row_chunk=block)
        return _knn.NeighborGraph(di, dv)
    if impl == "jnp":
        chunk = block
        m = -(-n // chunk) * chunk
        Xp = jnp.pad(X, ((0, m - n), (0, 0)))
        dv, di = _topk_select_jnp(Xp, k=k, metric=metric, chunk=chunk, n=n,
                                  tile=tile)
        return _knn.NeighborGraph(di.reshape(m, k)[:n],
                                  dv.reshape(m, k)[:n])
    # pallas / interpret: power-of-two candidate tile >= next_pow2(k),
    # rows padded to a multiple of both tiles (masked off via n_valid)
    kp = _next_pow2(k)
    bz = max(_next_pow2(min(int(tile), max(n, 1))), kp)
    bz = min(bz, _next_pow2(n))
    blk = 1
    while blk * 2 <= max(int(block), 1):
        blk *= 2                      # row tile rounded down to a pow2
    blk = min(blk, _next_pow2(n))
    q = max(blk, bz)                  # both pow2: lcm == max
    m = -(-n // q) * q
    Xp = jnp.pad(X, ((0, m - n), (0, 0)))
    dv, di = topk_pallas(Xp, k=k, metric=metric, n_valid=n, block=blk,
                         block_z=bz, interpret=impl == "interpret")
    return _knn.NeighborGraph(di[:n], dv[:n])


# --------------------------------------------------------------------------
# fused select -> cohere: the single-program sparse pipeline
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "metric", "chunk", "n",
                                             "tile", "ties"))
def _select_cohere_jnp(Xp, *, k: int, metric: str, chunk: int, n: int,
                       tile: int, ties=DEFAULT_TIES):
    """One jit for the whole sparse pipeline: each row slab is selected,
    gathered and scored inside the same lax.map step, so the freshly
    selected (chunk, k) neighbor values/indices feed the ``pald_knn`` tile
    body (``core.knn.knn_values_tile``) directly — no NeighborGraph, no
    intermediate HBM round-trip between the stages."""
    wfun = resolve_weight(ties)
    offs = jnp.arange(Xp.shape[0] // chunk) * chunk

    def body(off):
        dv, di = _topk_chunk(Xp, off, k=k, metric=metric, chunk=chunk, n=n,
                             tile=tile)
        g = _knn.gather_tile_from_features(Xp[:n], di, metric)
        ow = None
        if wfun.needs_index_tiebreak:
            ow = (off + jnp.arange(chunk))[:, None] > di
        return dv, di, _knn.knn_values_tile(dv, g, ow, wfun)

    return jax.lax.map(body, offs)


def select_cohere(
    X,
    *,
    k: int,
    metric: str = "euclidean",
    block: int | str = "auto",
    tile: int | str = "auto",
    cohere_block: int | str = "auto",
    impl: str | None = None,
    select: str | None = None,
    ties=DEFAULT_TIES,
    normalize: bool = False,
) -> tuple["_knn.NeighborGraph", jnp.ndarray]:
    """Fused streaming selection -> sparse cohesion from features.

    The from_features knn pipeline in one pass: neighbor selection (see
    ``topk_select``) feeds the ``pald_knn`` tile body without a host-side
    ``NeighborGraph`` in between.  On the jnp impl both stages trace into
    ONE jit — selection, the neighbor-to-neighbor feature gather and
    ``knn_values_tile`` share each lax.map step, so only one (block, n)
    distance slab is ever live.  On the Pallas impls the streaming
    selection kernel's (m, k) device outputs feed the cohesion kernel
    directly.  Bitwise equals the two-stage ``knn_from_features`` ->
    ``pald_knn`` composition for every weight functional (identical
    selection, identical tile body, chunking never changes a row).

    Args:
        X: (n, d) features.
        k: neighborhood size (clamped to n-1).
        block / tile: selection knobs (see ``topk_select``).
        cohere_block: row tile of the standalone cohesion pass — used only
            when selection and cohesion cannot fuse into one program
            (Pallas impls, 'chunked' selection); "auto" = ``pald_knn``
            cache.
        impl: cohesion impl ('pallas'/'interpret'/'jnp'); None = default.
        select: selection impl override; None = follow ``impl``.
        ties: weight functional; normalize: divide values by (n-1).

    Returns:
        (graph, values) — the selected NeighborGraph (returned for
        downstream analysis; built AFTER the fused compute) and the
        (n, k+1) sparse cohesion values (column 0 = self).
    """
    ties = resolve_weight(ties)
    impl = impl or _default_impl()
    sel = select or ("jnp" if impl == "jnp" else impl)
    fault_point("ops.select_cohere", impl=impl, select=sel, ties=ties.name)
    X = jnp.asarray(X, jnp.float32)
    n, d = X.shape
    k = min(int(k), max(n - 1, 0))
    if k <= 0:
        return (_knn.NeighborGraph(jnp.zeros((n, 0), jnp.int32),
                                   jnp.zeros((n, 0), jnp.float32)),
                jnp.zeros((n, 1), jnp.float32))
    if sel == "jnp" and impl == "jnp":
        block, tile = _resolve_topk_tiles(n, d, k, block, tile, sel)
        chunk = block
        m = -(-n // chunk) * chunk
        Xp = jnp.pad(X, ((0, m - n), (0, 0)))
        fault_point("ops.topk_select", impl=sel, metric=metric)
        dv, di, vals = _select_cohere_jnp(Xp, k=k, metric=metric,
                                          chunk=chunk, n=n, tile=tile,
                                          ties=ties)
        graph = _knn.NeighborGraph(di.reshape(m, k)[:n],
                                   dv.reshape(m, k)[:n])
        vals = vals.reshape(m, k + 1)[:n]
    else:
        # two kernels back-to-back: device arrays flow straight through
        graph = topk_select(X, k, metric=metric, impl=sel, block=block,
                            tile=tile)
        vals = knn_values(X, graph, kind="features", metric=metric,
                          block=cohere_block, impl=impl, ties=ties)
    if normalize:
        vals = vals / max(n - 1, 1)
    return graph, vals


# --------------------------------------------------------------------------
# engine executors: the kernel-pipeline cells of the dispatch registry
# (repro.core.engine).  Each receives one unbatched item plus the resolved
# plan; the plan's tiles/impl/ties were fixed once at plan() time, so these
# bodies never consult the tuning cache themselves.
# --------------------------------------------------------------------------
from repro.core import engine as _engine  # noqa: E402  (registry import)


def _kernel_exec(D, plan, pipeline):
    Dp, n0 = _engine.pad_distance_matrix(D, plan.block)  # f32 boundary cast
    nv = jnp.asarray(n0) if Dp.shape[0] != n0 else None
    kz = {} if plan.block_z is None else {"block_z": plan.block_z}
    C = pipeline(Dp, block=plan.block, n_valid=nv, impl=plan.impl,
                 ties=plan.weight, **kz)
    C = C[:n0, :n0]
    return C / max(n0 - 1, 1) if plan.normalize else C


@_engine.register_executor("distance", "kernel", "dense")
def _exec_kernel_dense(D, plan):
    return _kernel_exec(D, plan, pald)


@_engine.register_executor("distance", "kernel", "tri")
def _exec_kernel_tri(D, plan):
    return _kernel_exec(D, plan, pald_tri)


@_engine.register_executor("features", "fused", "dense")
def _exec_fused(X, plan):
    return pald_fused(X, metric=plan.metric, block=plan.block,
                      block_z=plan.block_z, normalize=plan.normalize,
                      impl=plan.impl, ties=plan.weight)


# -- sparse k-NN cells ------------------------------------------------------
# At k >= n-1 every point is every other point's neighbor: the restriction
# is the identity, and gathering the (n, n-1, n-1) neighbor cube would be
# strictly more work than the dense computation it reproduces.  The
# executors therefore run the exact dense path there — which also makes
# `cohesion(D, method="knn", k=n-1)` agree with `method="dense"` bitwise,
# the anchor of the knn→dense convergence contract (test_conformance.py).
# ``ops.pald_knn`` itself never short-circuits, so the sparse machinery
# stays testable at full k.
def _knn_dense_fallback(D, plan):
    return _engine.get_executor("distance", "dense", "dense")(D, plan)


@_engine.register_executor("distance", "knn", "dense")
def _exec_knn_distance(D, plan):
    D = jnp.asarray(D, jnp.float32)
    n = D.shape[0]
    if plan.k >= n - 1:
        return _knn_dense_fallback(D, plan)
    graph = None
    if plan.select == "chunked":
        # terminal selection rung: row-chunked lax.top_k over D's slabs
        graph = _knn_from_distances_chunked(D, plan.k)
    graph, vals = pald_knn(D, k=plan.k, kind="distance", block=plan.block,
                           impl=plan.impl, ties=plan.weight, graph=graph)
    C = _knn.scatter_dense(graph, vals)
    return C / max(n - 1, 1) if plan.normalize else C


@_engine.register_executor("features", "knn", "dense")
def _exec_knn_features(X, plan):
    """The fused select->cohere cell: selection streams straight into the
    pald_knn tile body (``select_cohere``) — no host-side NeighborGraph
    between the stages, no (n, n) intermediate ever."""
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    if plan.k >= n - 1:
        from repro.core.features import cdist_reference

        return _knn_dense_fallback(cdist_reference(X, metric=plan.metric),
                                   plan)
    if getattr(plan, "mesh", None) is not None:
        from repro.core import distributed_knn as _dknn

        graph, vals = _dknn.pald_knn_sharded(
            X, plan.mesh, k=plan.k, metric=plan.metric,
            strategy=plan.strategy or "auto", normalize=False,
            weight=plan.weight, block=plan.select_block or "auto",
            tile=plan.select_tile if plan.select_tile is not None
            else "auto", on_error="raise")
        C = _knn.scatter_dense(graph, vals)
        return C / max(n - 1, 1) if plan.normalize else C
    graph, vals = select_cohere(
        X, k=plan.k, metric=plan.metric,
        block=plan.select_block or "auto",
        tile=plan.select_tile if plan.select_tile is not None else "auto",
        cohere_block=plan.block, impl=plan.impl, select=plan.select,
        ties=plan.weight)
    C = _knn.scatter_dense(graph, vals)
    return C / max(n - 1, 1) if plan.normalize else C
