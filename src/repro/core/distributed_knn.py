"""Mesh-sharded k-NN PaLD: the fused select→cohere pipeline under shard_map.

``core/distributed.py`` shards the DENSE two-pass algorithm; this module
shards the sparse O(n·k²) restriction (PR 5) fused with the streaming
top-k selection (PR 9) so both stages run per shard and only the (n, k+1)
sparse result is ever global.  X is row-sharded over the (flattened) mesh;
each device selects the exact k nearest neighbors for its own rows, gathers
the (m, k, d) neighbor features it needs, and runs the ``pald_knn`` tile
body (``core.knn.knn_values_tile``) locally.  The full distance matrix is
never materialized anywhere — per-device live state is one (chunk, n/pc)
distance slab at a time.

Strategies (comm figures are f32 words received per device; see
``comm_estimate`` for the model the engine/dryrun report):

allgather   one ``all_gather`` of X — (p-1)/p · n·d words — then each shard
            runs the exact single-device row-slab pipeline on its own rows.
            Simplest; per-device memory O(n·d + chunk·n).
ring        no global X copy: (m, d) feature blocks rotate via ``ppermute``
            twice (selection, then neighbor gather), 2·(p-1)/p · n·d words.
            Running (m, k) best lists are merged EXACTLY each step by a
            lexicographic ``lax.sort`` on (distance, index) pairs — the
            same total order ``_top_k_rows`` selects by, so visit order
            cannot change the result.  Peak memory O(n·d/p + chunk·n/p).
2d          (pr, pc) mesh: each device scores its row-group's rows against
            the 1/pc column slice it owns — compute n²·d/(pr·pc) per
            device — takes a partial top-k, and one k-wide ``all_gather``
            + exact merge along the column axis finishes selection;
            comm n·d + 2·(pc-1)/pc · (n/pr)·k words.

Bitwise contract: every strategy reproduces the single-device fused path
(``kernels.ops.select_cohere``) row for row — selection merges on the
composite (value, index) key that defines ``_top_k_rows``'s order, the
neighbor-to-neighbor gather recomputes ``gather_tile_from_features``'s
exact shapes, and the values stage is the shared ``knn_values_tile`` whose
reductions run over the k axes only (per-row independent).  The one caveat
is inherited from the selection kernel (``kernels/pald_topk.py``): tile
distances come from a d-contraction GEMM whose summation order is
shape-stable on TPU but on XLA:CPU only for SIMD-clean d; integer-valued
features are exact in f32 regardless, which is what the conformance matrix
pins (tests/test_distributed_knn.py).

Padded rows (n not divisible by the shard quantum) enter selection as
masked (+inf, INT32_MAX) sentinel candidates — they lose every composite-
key comparison, so real rows never see them; the junk values computed FOR
padded rows are sliced off before returning.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.tuning import autotune as _tuner

from . import knn as _knn
from .distributed import shard_map_compat
from .features import METRICS, dist_tile
from .resilience import fault_point, warn_once
from .weights import DEFAULT_TIES, resolve_weight

__all__ = ["STRATEGIES", "pald_knn_sharded", "comm_estimate",
           "resolve_shard_shapes"]

STRATEGIES = ("auto", "allgather", "ring", "2d")

_IMAX = 2 ** 31 - 1  # the (value, index) sentinel: loses every comparison


def _merge_pairs(v, i, k: int):
    """Exact top-k of composite (value, index) pairs along the last axis.

    ``lax.sort`` with two keys orders lexicographically ascending — the
    SAME total order ``core.knn._top_k_rows`` (stable ``lax.top_k`` on
    negated distances) selects by.  Real candidates all carry distinct
    indices, so the order is total and merging partial lists in ANY
    grouping reproduces the single-device selection bitwise."""
    sv, si = jax.lax.sort((v, i), dimension=v.ndim - 1, num_keys=2,
                          is_stable=True)
    return sv[..., :k], si[..., :k]


# ---------------------------------------------------------------------------
# shard bodies (each returns the (mloc, k) / (mloc, k+1) row-sharded triple)
# ---------------------------------------------------------------------------
def _knn_allgather_body(Xloc, *, axis, k, metric, n, chunk, tile, wfun):
    """One all_gather of X, then the exact single-device row-slab loop
    (``ops._topk_chunk`` → gather → ``knn_values_tile``) over own rows."""
    from repro.kernels import ops as _ops

    m = Xloc.shape[0]
    Xall = jax.lax.all_gather(Xloc, axis, tiled=True)       # (mtot, d)
    off0 = jax.lax.axis_index(axis) * m

    def body(j):
        off = off0 + j * chunk
        dv, di = _ops._topk_chunk(Xall, off, k=k, metric=metric,
                                  chunk=chunk, n=n, tile=tile)
        g = _knn.gather_tile_from_features(Xall[:n], di, metric)
        ow = None
        if wfun.needs_index_tiebreak:
            ow = (off + jnp.arange(chunk))[:, None] > di
        return dv, di, _knn.knn_values_tile(dv, g, ow, wfun)

    dv, di, vals = jax.lax.map(body, jnp.arange(m // chunk))
    return (dv.reshape(m, k), di.reshape(m, k), vals.reshape(m, k + 1))


def _knn_ring_body(Xloc, *, axis, p, k, metric, n, chunk, wfun):
    """Streaming selection: (m, d) feature blocks rotate via ppermute; the
    running (m, k) best list merges each step's candidates exactly on the
    (value, index) key.  A second rotation replays the blocks to gather the
    selected neighbors' features, then cohesion runs fully locally."""
    m, d = Xloc.shape
    r = jax.lax.axis_index(axis)
    fwd = [(j, (j + 1) % p) for j in range(p)]
    gids = r * m + jnp.arange(m)
    nc = m // chunk

    def sel_step(s, carry):
        blk, bv, bi = carry
        off = ((r - s) % p) * m              # global offset of blk's rows
        cols = (off + jnp.arange(m)).astype(jnp.int32)

        def row_chunk(j, st):
            bv, bi = st
            rows = jax.lax.dynamic_slice(Xloc, (j * chunk, 0), (chunk, d))
            rid = jax.lax.dynamic_slice(gids, (j * chunk,), (chunk,))
            dt = dist_tile(rows, blk, metric)               # (chunk, m)
            bad = (rid[:, None] == cols[None, :]) | (cols >= n)[None, :]
            # pre-reduce the block with the SAME stable top_k primitive
            # the single-device kernel uses: within one block, column
            # order == ascending global id, so (value, column) order is
            # (value, id) order and the kb survivors are exactly the
            # entries a full-width merge would keep (masked entries all
            # carry the identical (+inf, _IMAX) composite key).  The
            # running merge then sorts k + kb pairs instead of k + m.
            kb = min(k, m)
            cv, loc = _knn._top_k_rows(
                jnp.where(bad, -jnp.inf, -dt), kb)          # (chunk, kb)
            ci = jnp.where(jnp.isinf(cv), jnp.int32(_IMAX),
                           (off + loc).astype(jnp.int32))
            obv = jax.lax.dynamic_slice(bv, (j * chunk, 0), (chunk, k))
            obi = jax.lax.dynamic_slice(bi, (j * chunk, 0), (chunk, k))
            mv, mi = _merge_pairs(jnp.concatenate([obv, cv], axis=1),
                                  jnp.concatenate([obi, ci], axis=1), k)
            return (jax.lax.dynamic_update_slice(bv, mv, (j * chunk, 0)),
                    jax.lax.dynamic_update_slice(bi, mi, (j * chunk, 0)))

        bv, bi = jax.lax.fori_loop(0, nc, row_chunk, (bv, bi))
        return jax.lax.ppermute(blk, axis, fwd), bv, bi

    bv = jnp.full((m, k), jnp.inf, jnp.float32)
    bi = jnp.full((m, k), jnp.int32(_IMAX))
    _, bv, bi = jax.lax.fori_loop(
        0, p, lambda s, c: sel_step(s, c), (Xloc, bv, bi))

    # rotation 2: replay the blocks to collect the selected neighbors'
    # feature rows (each global index lives in exactly one block)
    def gat_step(s, carry):
        blk, Xn = carry
        off = ((r - s) % p) * m
        safe = jnp.where(bi < n, bi, 0)
        loc = safe - off
        inr = (loc >= 0) & (loc < m) & (bi < n)
        sel = blk[jnp.clip(loc, 0, m - 1)]                  # (m, k, d)
        Xn = jnp.where(inr[:, :, None], sel, Xn)
        return jax.lax.ppermute(blk, axis, fwd), Xn

    _, Xn = jax.lax.fori_loop(
        0, p, lambda s, c: gat_step(s, c),
        (Xloc, jnp.zeros((m, k, d), jnp.float32)))

    # cohesion: same (chunk, k) tiles as the single-device fused loop;
    # the gathered Xn rows equal X[bi] exactly, so the per-row g cube
    # matches gather_tile_from_features (same shapes, same zero diagonal)
    def coh(j):
        bvj = jax.lax.dynamic_slice(bv, (j * chunk, 0), (chunk, k))
        bij = jax.lax.dynamic_slice(bi, (j * chunk, 0), (chunk, k))
        Xnj = jax.lax.dynamic_slice(Xn, (j * chunk, 0, 0), (chunk, k, d))
        G = jax.vmap(lambda A: dist_tile(A, A, metric))(Xnj)
        g = jnp.where(bij[:, :, None] == bij[:, None, :], 0.0, G)
        ow = None
        if wfun.needs_index_tiebreak:
            rid = jax.lax.dynamic_slice(gids, (j * chunk,), (chunk,))
            ow = rid[:, None] > bij
        return _knn.knn_values_tile(bvj, g, ow, wfun)

    vals = jax.lax.map(coh, jnp.arange(nc)).reshape(m, k + 1)
    return bv, bi, vals


def _knn_2d_body(Xloc, *, row_axes, col_axis, k, metric, n, chunk, wfun,
                 pr, pc):
    """2-D decomposition: the (pr, pc) mesh splits the n² selection compute
    both ways.  Each device scores its row-group's (n/pr) rows against the
    strided 1/pc candidate slice it owns, takes a partial top-k, and the
    column axis all_gathers + exactly merges the k-wide partials."""
    mloc, d = Xloc.shape
    allax = (*row_axes, col_axis)
    flat = jax.lax.axis_index(allax)        # row-major flattened device id
    ci = jax.lax.axis_index(col_axis)
    gids = flat * mloc + jnp.arange(mloc)

    # one all_gather of X (needed for the neighbor gather regardless);
    # flattened axis order == global row order by the in_spec construction
    Xall = jax.lax.all_gather(Xloc, allax, tiled=True)      # (mtot, d)
    rowids = jax.lax.all_gather(gids, col_axis, tiled=True)  # contiguous
    candids = jax.lax.all_gather(gids, row_axes, tiled=True)  # strided
    Xrow = jax.lax.all_gather(Xloc, col_axis, tiled=True)    # (mr, d)
    Xcand = jax.lax.all_gather(Xloc, row_axes, tiled=True)   # (mc, d)
    mr, mc = Xrow.shape[0], Xcand.shape[0]
    kt = min(k, mc)         # each block's top-kt covers the global top-k
    cids = candids.astype(jnp.int32)

    def rchunk(j):
        rows = jax.lax.dynamic_slice(Xrow, (j * chunk, 0), (chunk, d))
        rid = jax.lax.dynamic_slice(rowids, (j * chunk,), (chunk,))
        dt = dist_tile(rows, Xcand, metric)                 # (chunk, mc)
        bad = (rid[:, None] == cids[None, :]) | (cids >= n)[None, :]
        # stable top_k pre-reduction (see the ring body): the gathered
        # candidate blocks arrive in ascending flat-device order, so
        # ``cids`` is strictly increasing and (value, column) order is
        # (value, id) order — the kt survivors match a full-width sort
        cv, loc = _knn._top_k_rows(jnp.where(bad, -jnp.inf, -dt), kt)
        civ = jnp.where(jnp.isinf(cv), jnp.int32(_IMAX), cids[loc])
        return cv, civ

    pv, pi = jax.lax.map(rchunk, jnp.arange(mr // chunk))
    pv, pi = pv.reshape(mr, kt), pi.reshape(mr, kt)
    # merge the pc partial lists (disjoint candidate sets) exactly
    av = jax.lax.all_gather(pv, col_axis, axis=1, tiled=True)  # (mr, pc*kt)
    ai = jax.lax.all_gather(pi, col_axis, axis=1, tiled=True)
    dv, di = _merge_pairs(av, ai, k)

    # this device's original rows sit at column-position ci in the slab
    dvo = jax.lax.dynamic_slice(dv, (ci * mloc, 0), (mloc, k))
    dio = jax.lax.dynamic_slice(di, (ci * mloc, 0), (mloc, k))

    def coh(j):
        dvj = jax.lax.dynamic_slice(dvo, (j * chunk, 0), (chunk, k))
        dij = jax.lax.dynamic_slice(dio, (j * chunk, 0), (chunk, k))
        g = _knn.gather_tile_from_features(Xall[:n], dij, metric)
        ow = None
        if wfun.needs_index_tiebreak:
            rid = jax.lax.dynamic_slice(gids, (j * chunk,), (chunk,))
            ow = rid[:, None] > dij
        return _knn.knn_values_tile(dvj, g, ow, wfun)

    vals = jax.lax.map(coh, jnp.arange(mloc // chunk)).reshape(mloc, k + 1)
    return dvo, dio, vals


# ---------------------------------------------------------------------------
# shapes + communication model (consumed by engine.explain and dryrun_pald)
# ---------------------------------------------------------------------------
def resolve_shard_shapes(n: int, *, p: int, chunk: int) -> tuple[int, int, int]:
    """(chunk, quantum, m_padded): the one place the padding math lives.

    ``chunk`` is clamped to the per-shard row count so the slab loop always
    has at least one full tile; the global quantum is ``p * chunk`` so
    every shard's row count is a chunk multiple."""
    chunk = max(1, min(int(chunk), -(-n // p)))
    quantum = p * chunk
    m = -(-n // quantum) * quantum
    return chunk, quantum, m


def comm_estimate(strategy: str, *, n: int, d: int, k: int, p: int,
                  pr: int | None = None, pc: int | None = None) -> dict:
    """Per-device communication model of the sharded knn pipeline.

    Words are f32 words RECEIVED per device (ppermute/all_gather payloads;
    int32 index words count as one word).  Every strategy moves O(n·d)
    feature words — never the O(n²) distance matrix — matching the
    module docstring's ``comm n·d`` claim and the source paper's
    communication-optimality analysis; the 2d strategy adds the
    O((n/pr)·k) selection-merge term.

    Returns a dict with ``per_device_words``, ``total_words`` (sum over
    devices), and the per-collective breakdown.
    """
    if strategy == "auto":
        strategy = "2d" if (pr or 0) > 0 and (pc or 0) > 1 else "ring"
    mloc = -(-n // p)
    if strategy == "allgather":
        parts = {"allgather_x": (p - 1) * mloc * d}
    elif strategy == "ring":
        parts = {"ring_select_x": (p - 1) * mloc * d,
                 "ring_gather_x": (p - 1) * mloc * d}
    elif strategy == "2d":
        pr = pr or 1
        pc = pc or p
        mr = -(-n // pr)
        kt = min(k, pr * mloc)
        parts = {"allgather_x": (p - 1) * mloc * d,
                 "allgather_ids": (p - 1) * mloc + (pc - 1) * mloc
                 + (pr - 1) * mloc,
                 "rowcand_slabs": (pc - 1) * mloc * d + (pr - 1) * mloc * d,
                 "merge_partials": 2 * (pc - 1) * mr * kt}
    else:
        raise ValueError(f"unknown strategy {strategy!r} "
                         f"(expected one of {STRATEGIES[1:]})")
    per_dev = int(sum(parts.values()))
    return {"strategy": strategy, "p": p,
            "per_device_words": per_dev,
            "per_device_bytes": 4 * per_dev,
            "total_words": per_dev * p,
            "breakdown": {kk: int(v) for kk, v in parts.items()}}


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------
def pald_knn_sharded(
    X: jnp.ndarray,
    mesh: Mesh,
    *,
    k: int,
    metric: str = "euclidean",
    strategy: str = "auto",
    normalize: bool = True,
    ties=None,
    weight=None,
    block: int | str = "auto",
    tile: int | str = "auto",
    on_error: str = "raise",
) -> tuple["_knn.NeighborGraph", jnp.ndarray]:
    """Mesh-sharded fused select→cohere k-NN PaLD from features.

    Args:
        X: host/global (n, d) feature matrix (cast to float32 once).
        mesh: the ``jax.sharding.Mesh`` to run on.  1-D strategies flatten
            every axis; "2d" uses all-but-last as row axes and the last as
            the column (selection-split) axis.
        k: neighborhood size (clamped to n-1, like ``select_cohere``).
        metric: one of ``features.METRICS``.
        strategy: "allgather" / "ring" / "2d", or "auto" — "2d" on a
            multi-axis mesh, "ring" otherwise (mirrors
            ``pald_distributed``'s convention).  See the module docstring
            for the comm/memory trade.
        normalize: divide values by (n-1) (the public-API default).
        ties / weight: the weight-functional knob, exactly as in
            ``pald.from_features`` (``ties`` sugar over ``weight``).
        block: rows per selection slab per shard; "auto" resolves via the
            mesh-keyed ``pald_topk:k<k>:d<d>:p<p>`` tuning pass (falling
            back to the single-device cell on a miss).
        tile: tile-min prefilter width (allgather strategy only — ring/2d
            stream column blocks instead of prefiltering); "auto" = tuned.
        on_error: "raise" propagates any sharded failure; "fallback"
            degrades to the single-device fused pipeline
            (``kernels.ops.select_cohere``) with identical semantics,
            warning once (``resilience.DegradationWarning``).

    Returns:
        (graph, values): the exact ``NeighborGraph`` (n, k) and the
        (n, k+1) sparse cohesion values (column 0 = self) — bitwise equal
        to single-device ``select_cohere(X, k=..., ...)`` per the module
        contract.

    Raises:
        ValueError: unknown strategy/metric, or "2d" on a 1-axis mesh.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} "
                         f"(expected one of {STRATEGIES})")
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r} (one of {METRICS})")
    axes = tuple(mesh.axis_names)
    if strategy == "auto":
        strategy = "2d" if len(axes) >= 2 else "ring"
    if strategy == "2d" and len(axes) < 2:
        raise ValueError("strategy '2d' needs a mesh with >= 2 axes "
                         f"(got axes {axes}); use 'allgather' or 'ring'")
    wfun = resolve_weight(weight if weight is not None
                          else (ties if ties is not None else DEFAULT_TIES))

    X = jnp.asarray(X, jnp.float32)
    n0, d = X.shape
    k = min(int(k), max(n0 - 1, 0))
    if k <= 0:
        return (_knn.NeighborGraph(jnp.zeros((n0, 0), jnp.int32),
                                   jnp.zeros((n0, 0), jnp.float32)),
                jnp.zeros((n0, 1), jnp.float32))

    p = mesh.devices.size
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pr = math.prod(sizes[a] for a in axes[:-1]) if len(axes) >= 2 else 1
    pc = sizes[axes[-1]]

    if block == "auto" or tile == "auto":
        rb, rt = _tuner.resolve_blocks(n0, "pald_topk", impl="jnp", d=d,
                                       k=k, p=p)
        block = rb if block == "auto" else block
        tile = rt if tile == "auto" else tile
    chunk, _, m = resolve_shard_shapes(n0, p=p, chunk=int(block))

    fault_point("distributed_knn.dispatch", strategy=strategy, p=p, k=k,
                metric=metric)

    def run_sharded():
        Xp = jnp.pad(X, ((0, m - n0), (0, 0)))
        if strategy == "allgather":
            body = functools.partial(
                _knn_allgather_body, axis=axes, k=k, metric=metric, n=n0,
                chunk=chunk, tile=int(tile), wfun=wfun)
        elif strategy == "ring":
            body = functools.partial(
                _knn_ring_body, axis=axes, p=p, k=k, metric=metric, n=n0,
                chunk=chunk, wfun=wfun)
        else:
            body = functools.partial(
                _knn_2d_body, row_axes=axes[:-1], col_axis=axes[-1], k=k,
                metric=metric, n=n0, chunk=chunk, wfun=wfun, pr=pr, pc=pc)
        fault_point("distributed_knn.body", strategy=strategy, p=p,
                    mesh=tuple(mesh.devices.shape))
        spec = P(axes, None)
        fn = jax.jit(shard_map_compat(
            body, mesh=mesh, in_specs=spec,
            out_specs=(spec, spec, spec)))
        Xs = jax.device_put(Xp, NamedSharding(mesh, spec))
        dv, di, vals = fn(Xs)
        return dv[:n0], di[:n0], vals[:n0]

    if on_error == "fallback":
        try:
            dv, di, vals = run_sharded()
        except Exception as exc:  # noqa: BLE001 — the guard's whole job
            from repro.kernels import ops as _ops

            warn_once(("distributed-knn", strategy, tuple(mesh.devices.shape)),
                      f"sharded knn pipeline (strategy={strategy!r}, mesh="
                      f"{tuple(mesh.devices.shape)}) failed "
                      f"({type(exc).__name__}: {exc}); degraded to the "
                      "single-device fused path with identical semantics")
            graph, vals = _ops.select_cohere(
                X, k=k, metric=metric, block=chunk, tile=int(tile)
                if strategy == "allgather" else "auto", impl="jnp",
                ties=wfun, normalize=normalize)
            return graph, vals
    else:
        dv, di, vals = run_sharded()
    if normalize:
        vals = vals / max(n0 - 1, 1)
    return _knn.NeighborGraph(di, dv), vals
