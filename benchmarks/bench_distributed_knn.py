"""Mesh-sharded knn PaLD: points/sec vs device count (ISSUE 10).

Each (n, d, k) cell is run at every device count in ``ps``: p=1 is the
single-device fused select->cohere pipeline (the PR 9 baseline a caller
gets with no ``mesh=``), p>1 shards rows across a 1-axis mesh of forced
host devices (or real accelerators when present) with the given strategy.
The ``speedup_vs_p1`` column is the scaling curve the CI gate consumes.

Honesty note for CPU runners: forced host devices all share the same
cores, so p>1 measures the sharding OVERHEAD there, not a speedup — the
gate in ci.yml applies a no-regression floor on CPU and the >= 2x
requirement only where devices are real (see BENCH_PR10.json gate row).

The full-scale entry point ``run_scale`` lands the n=10^6 end-to-end run:
row-sharded streaming selection + sparse cohesion, never materializing
the (n, n) distance matrix — (n, k+1) sparse output only.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _features(n: int, d: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    # clustered gaussian blobs: realistic neighborhood structure
    centers = rng.normal(scale=4.0, size=(max(8, n // 1000), d))
    X = centers[rng.integers(0, len(centers), n)] + rng.normal(size=(n, d))
    return jnp.asarray(X, jnp.float32)


def _time_once(fn, *args, warm: bool = True) -> float:
    if warm:
        jax.block_until_ready(fn(*args))  # warmup + compile
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _cell(X, k: int, p: int, strategy: str, block, warm: bool = True) -> float:
    from repro.core import distributed_knn as dknn
    from repro.kernels import ops
    from repro.launch import mesh as meshlib

    if p == 1:
        return _time_once(
            lambda A: ops.select_cohere(A, k=k, impl="jnp",
                                        block=block, normalize=True), X,
            warm=warm)
    mesh = meshlib.make_test_mesh((p,), ("data",))
    return _time_once(
        lambda A: dknn.pald_knn_sharded(A, mesh, k=k, strategy=strategy,
                                        block=block), X, warm=warm)


def run(cells=((4096, 8, 16), (16384, 8, 16)), ps=(1, 2, 4),
        strategy: str = "ring", block="auto",
        warm: bool = True) -> list[dict]:
    rows: list[dict] = []
    avail = len(jax.devices())
    for n, d, k in cells:
        X = _features(n, d)
        base = None
        for p in ps:
            if p > avail:
                continue
            sec = _cell(X, k, p, strategy, block, warm=warm)
            if p == 1:
                base = sec
            rows.append({
                "n": n, "d": d, "k": k, "p": p,
                "strategy": "fused" if p == 1 else strategy,
                "seconds": round(sec, 4),
                "points_per_sec": round(n / sec, 1),
                "speedup_vs_p1": round(base / sec, 3) if base else 1.0,
            })
    return rows


def run_scale(n: int = 1_000_000, d: int = 4, k: int = 8,
              ps=(1, 4), strategy: str = "ring",
              block: int = 4096) -> list[dict]:
    """The n=10^6 end-to-end scaling curve (full mode only).

    An explicit large ``block`` keeps the host-side chunk loop short; the
    sparse output is (n, k+1) floats (~36 MB at the defaults) and X is
    (n, d) (~16 MB) — the 10^12-entry distance matrix never exists.
    Each cell is timed cold (single run, compile included): at ~1 hour a
    cell on a single-core host a warmup repeat would double an already
    compile-dominated-by-nothing measurement for < 1% accuracy.
    """
    return run(cells=((n, d, k),), ps=ps, strategy=strategy, block=block,
               warm=False)
