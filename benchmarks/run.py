"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]

Sections:
    fig3    optimization waterfall        (bench_optimizations)
    fig4    block-size tuning             (bench_blocksize)
    table1  pairwise vs triplet           (bench_variants)
    table1b dense vs tri kernel schedule  (bench_variants.run_kernels)
    table1c fused features vs materialize (bench_variants.run_fused)
    weights soft/kernelized vs drop       (bench_variants.run_weights)
    knn     sparse k-NN vs best dense     (bench_knn)
    selection streaming top-k + fusion    (bench_knn.run_selection)
    dispatch plan+execute overhead        (bench_variants.run_dispatch)
    batched  (B,n,n) engine throughput    (bench_variants.run_batched)
    fig9+   scaling + comm model          (bench_scaling)
    sec7    text-analysis application     (bench_text_analysis)
    roofline summary of dry-run JSONs     (roofline), if present

``--fast`` additionally writes a machine-readable ``BENCH_PR<k>.json``
(per-section rows + wall timings) next to this file so the perf trajectory
is tracked across PRs; ``<k>`` comes from $REPRO_PR_INDEX or the next free
integer.  ``--json PATH`` overrides the output location.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time


def _json_path(explicit: str | None) -> str:
    here = os.path.dirname(__file__)
    if explicit:
        return explicit
    env = os.environ.get("REPRO_PR_INDEX")
    if env:
        return os.path.join(here, f"BENCH_PR{env}.json")
    taken = set()
    for p in glob.glob(os.path.join(here, "BENCH_PR*.json")):
        tag = os.path.basename(p)[len("BENCH_PR"):-len(".json")]
        if tag.isdigit():
            taken.add(int(tag))
    k = max(taken, default=0) + 1
    return os.path.join(here, f"BENCH_PR{k}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    ap.add_argument("--json", default=None,
                    help="write the machine-readable report here "
                         "(default BENCH_PR<k>.json in --fast mode)")
    args = ap.parse_args()

    t0 = time.time()
    from . import (bench_blocksize, bench_distributed_knn, bench_knn,
                   bench_optimizations, bench_scaling, bench_text_analysis,
                   bench_variants, common)

    sections: dict[str, dict] = {}

    def section(name: str, header: str, fn) -> None:
        s0 = time.time()
        rows = fn()
        common.emit(rows, header=header)
        sections[name] = {"rows": rows, "seconds": round(time.time() - s0, 3)}

    if args.fast:
        section("fig3", "fig3: optimization waterfall (n=512, --fast)",
                lambda: bench_optimizations.run(n=512, n_naive=96))
        section("fig4", "fig4: block-size tuning (n=512, --fast)",
                lambda: bench_blocksize.run(n=512, blocks=(32, 64, 128, 256)))
        section("table1", "table1: pairwise vs triplet (--fast)",
                lambda: bench_variants.run(ns=(128, 256, 512)))
        section("table1b",
                "table1b: dense vs tri kernel schedule (jnp impl, --fast)",
                lambda: bench_variants.run_kernels(ns=(512, 1024)))
        section("fused",
                "table1c: fused features vs materialize-then-kernel (--fast)",
                lambda: bench_variants.run_fused(ns=(256, 1024)))
        section("ties",
                "ties: split/ignore tile-body overhead vs strict drop (--fast)",
                lambda: bench_variants.run_ties(ns=(256, 512, 1024)))
        section("weights",
                "weights: soft/kernelized tile-body overhead vs drop (--fast)",
                lambda: bench_variants.run_weights(ns=(256, 512)))
        section("knn",
                "knn: sparse k-NN PaLD vs best dense path (n x k, --fast)",
                lambda: bench_knn.run(ns=(1024, 4096), ks=(16, 32, 64)))
        section("selection",
                "selection: streaming top-k + fused select->cohere "
                "(n x k x d, --fast)",
                lambda: bench_knn.run_selection(
                    cells=((1024, 16, 8), (4096, 32, 8), (4096, 32, 4))))
        section("dispatch",
                "engine: plan+execute dispatch overhead vs direct call (--fast)",
                lambda: bench_variants.run_dispatch(ns=(256, 512)))
        section("batched",
                "engine: batched (B,n,n)/(B,n,d) throughput vs per-item loop "
                "(--fast)",
                lambda: bench_variants.run_batched(
                    cells=((3, 128), (3, 256), (2, 512))))
        section("distributed_knn",
                "distributed_knn: mesh-sharded select->cohere points/sec "
                "vs devices (--fast)",
                lambda: bench_distributed_knn.run(
                    cells=((4096, 8, 16),), ps=(1, 2, 4)))
    else:
        section("fig3", "fig3: optimization waterfall",
                bench_optimizations.run)
        section("fig4", "fig4: block-size tuning (n=1024)",
                bench_blocksize.run)
        section("table1", "table1: pairwise vs triplet", bench_variants.run)
        section("table1b", "table1b: dense vs tri kernel schedule (jnp impl)",
                bench_variants.run_kernels)
        section("fused",
                "table1c: fused features vs materialize-then-kernel",
                bench_variants.run_fused)
        section("ties",
                "ties: split/ignore tile-body overhead vs strict drop",
                bench_variants.run_ties)
        section("weights",
                "weights: soft/kernelized tile-body overhead vs drop",
                bench_variants.run_weights)
        section("knn",
                "knn: sparse k-NN PaLD vs best dense path (n x k)",
                lambda: bench_knn.run(ns=(1024, 4096, 8192),
                                      ks=(16, 32, 64, 128)))
        section("selection",
                "selection: streaming top-k + fused select->cohere "
                "(n x k x d)",
                lambda: bench_knn.run_selection(
                    cells=((1024, 16, 8), (4096, 32, 8), (4096, 32, 4),
                           (8192, 32, 8), (8192, 64, 8))))
        section("dispatch",
                "engine: plan+execute dispatch overhead vs direct call",
                lambda: bench_variants.run_dispatch(ns=(256, 512, 1024)))
        section("batched",
                "engine: batched (B,n,n)/(B,n,d) throughput vs per-item loop",
                lambda: bench_variants.run_batched(
                    cells=((4, 256), (4, 512), (2, 1024))))
        section("distributed_knn",
                "distributed_knn: mesh-sharded select->cohere points/sec "
                "vs devices",
                lambda: bench_distributed_knn.run(
                    cells=((16384, 8, 16), (65536, 8, 16)), ps=(1, 2, 4, 8)))
        section("distributed_knn_scale",
                "distributed_knn: n=10^6 end-to-end scaling curve",
                bench_distributed_knn.run_scale)
    section("scaling_measured", "fig9: measured scaling",
            bench_scaling.measured)
    section("comm_model", "comm model (n=100k analytic)",
            bench_scaling.comm_model)
    section("sec7", "sec7: text-analysis application", bench_text_analysis.run)
    from . import bench_graphs
    if args.fast:
        section("appendixC", "appendixC: PaLD on graph APSP (--fast)",
                lambda: bench_graphs.run(ns=(256,)))
    else:
        section("appendixC", "appendixC: PaLD on graph APSP", bench_graphs.run)

    here = os.path.dirname(__file__)
    from . import roofline
    for tag, sub in [("baseline", "dryrun_out"), ("optimized", "dryrun_out_opt")]:
        dr = os.path.join(here, sub)
        if os.path.isdir(dr) and os.listdir(dr):
            print(f"# roofline ({tag} dry-run dumps)")
            print(roofline.render(roofline.load(dr)))
            print()
    pald = os.path.join(here, "dryrun_out_pald")
    if os.path.isdir(pald) and os.listdir(pald):
        print("# pald workload dry-run (paper technique at pod scale)")
        print("| workload | strategy | mesh | GiB/dev | coll GiB/chip | compute_s | coll_s | bottleneck |")
        print("|---|---|---|---|---|---|---|---|")
        for p in sorted(glob.glob(os.path.join(pald, "*.json"))):
            c = json.load(open(p))
            if c.get("status") != "ok":
                print(f"| {os.path.basename(p)} | — | — | — | — | — | — | ERROR |")
                continue
            m = c["memory_analysis"]
            gib = (m.get("temp_size_in_bytes", 0) + m.get("argument_size_in_bytes", 0)) / 2**30
            r = c["roofline"]
            print(f"| {c['workload']} ({c.get('dtype','f32')}) | {c['strategy']} | {c['mesh']} "
                  f"| {gib:.2f} | {c['coll_bytes_per_chip']/2**30:.2f} "
                  f"| {r['compute_s']:.2f} | {r['collective_s']:.3f} | {r['bottleneck']} |")
        print()
    total = time.time() - t0
    if args.fast or args.json:
        import jax
        out = _json_path(args.json)
        report = {
            "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "fast": bool(args.fast),
            "backend": jax.default_backend(),
            "total_seconds": round(total, 2),
            "sections": sections,
        }
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {out}")
    print(f"# benchmarks done in {total:.1f}s")


if __name__ == "__main__":
    main()
