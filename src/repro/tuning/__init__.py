"""Persistent block-size autotuning for the PaLD kernel pipeline."""
from .autotune import (  # noqa: F401
    cache_path,
    load_cache,
    lookup,
    lookup_nearest,
    method_for,
    random_distance_matrix,
    resolve_blocks,
    save_entry,
    time_fn,
    tune,
    tune_methods,
)
