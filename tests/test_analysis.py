"""Direct edge-case coverage for core/analysis.py.

The community-extraction helpers were previously only exercised through
end-to-end cluster tests; this file pins their behavior on the degenerate
inputs a serving path will eventually see: a single point, a graph with no
strong ties at all, and a fully-connected strong-tie graph — plus the
``top_ties`` k-clamp fix (k > n-1 used to return padded garbage rows).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import analysis, pald


def _C(D):
    return np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))


@pytest.fixture
def two_cluster_C(rng):
    a = rng.normal(size=(6, 3)) * 0.5
    b = rng.normal(size=(6, 3)) * 0.5 + 30.0
    X = np.vstack([a, b])
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return _C(D)


# ---------------------------------------------------------------------------
# n=1
# ---------------------------------------------------------------------------
def test_n1_threshold_ties_communities():
    C = np.zeros((1, 1))
    assert analysis.universal_threshold(C) == 0.0
    S = analysis.strong_ties(C)
    assert S.shape == (1, 1) and S[0, 0] == 0.0
    assert analysis.communities(C) == [[0]]
    assert analysis.top_ties(C, 0, k=5) == []


# ---------------------------------------------------------------------------
# all-weak ties: nothing exceeds the threshold -> all singletons
# ---------------------------------------------------------------------------
def test_all_weak_ties_gives_singletons():
    n = 6
    C = np.full((n, n), 0.01)
    np.fill_diagonal(C, 1.0)  # tau = 0.5 >> every off-diagonal entry
    S = analysis.strong_ties(C)
    assert (S == 0).all()
    comms = analysis.communities(C)
    assert len(comms) == n
    assert all(len(c) == 1 for c in comms)
    assert sorted(i for c in comms for i in c) == list(range(n))


# ---------------------------------------------------------------------------
# fully connected: everything exceeds the threshold -> one community
# ---------------------------------------------------------------------------
def test_fully_connected_single_community():
    n = 5
    C = np.full((n, n), 0.9)
    np.fill_diagonal(C, 0.2)  # tau = 0.1 << every off-diagonal entry
    S = analysis.strong_ties(C)
    off = ~np.eye(n, dtype=bool)
    assert (S[off] == 0.9).all() and (np.diag(S) == 0).all()
    comms = analysis.communities(C)
    assert comms == [list(range(n))]


def test_strong_ties_explicit_threshold_overrides_universal():
    C = np.full((3, 3), 0.5)
    np.fill_diagonal(C, 1.0)
    assert (analysis.strong_ties(C, threshold=0.6) == 0).all()
    S = analysis.strong_ties(C, threshold=0.4)
    assert (S[~np.eye(3, dtype=bool)] == 0.5).all()


# ---------------------------------------------------------------------------
# top_ties k-clamp: k > n-1 must not emit the -inf self-sentinel
# ---------------------------------------------------------------------------
def test_top_ties_clamps_k(two_cluster_C):
    C = two_cluster_C
    n = C.shape[0]
    ties = analysis.top_ties(C, 0, k=n + 25)
    assert len(ties) == n - 1                       # clamped, not padded
    idxs = [i for i, _ in ties]
    assert 0 not in idxs                            # never ties to itself
    assert sorted(idxs) == [i for i in range(n) if i != 0]
    assert all(np.isfinite(v) for _, v in ties)     # no -inf garbage
    vals = [v for _, v in ties]
    assert vals == sorted(vals, reverse=True)


def test_top_ties_k_zero_and_negative(two_cluster_C):
    assert analysis.top_ties(two_cluster_C, 3, k=0) == []
    assert analysis.top_ties(two_cluster_C, 3, k=-2) == []


# ---------------------------------------------------------------------------
# communities determinism: equal-size components must come back in a
# data-defined order (smallest member first), not union-find-root order
# ---------------------------------------------------------------------------
def test_communities_equal_size_tiebreak_deterministic():
    # three 2-cliques with identical tie strength: sizes all equal, so the
    # order is entirely the tie-break's job
    n = 6
    C = np.full((n, n), 0.01)
    np.fill_diagonal(C, 1.0)
    for a, b in [(4, 5), (0, 1), (2, 3)]:
        C[a, b] = C[b, a] = 0.9
    comms = analysis.communities(C)
    assert comms == [[0, 1], [2, 3], [4, 5]]
    # permutation-relabelled input gives the relabelled (re-sorted) answer,
    # independent of the edge iteration order union-find saw
    perm = np.array([5, 3, 1, 0, 4, 2])
    Cp = C[np.ix_(perm, perm)]
    comms_p = analysis.communities(Cp)
    inv = {int(p): i for i, p in enumerate(perm)}
    expect = sorted(
        (sorted(inv[m] for m in c) for c in comms), key=lambda g: (-len(g), g[0])
    )
    assert comms_p == expect


def test_communities_size_still_dominates_tiebreak():
    # a 3-clique containing the LARGEST index must still sort before a
    # 2-clique containing index 0
    n = 5
    C = np.full((n, n), 0.01)
    np.fill_diagonal(C, 1.0)
    for a, b in [(2, 3), (3, 4), (2, 4), (0, 1)]:
        C[a, b] = C[b, a] = 0.9
    assert analysis.communities(C) == [[2, 3, 4], [0, 1]]
