"""Distributed PaLD under shard_map on a fake 8-device mesh vs reference."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distributed, reference
from repro.launch import mesh as meshlib

from conftest import euclidean_distance_matrix

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)


def _ref(D):
    return reference.pald_pairwise_reference(D, ties="ignore", normalize=True)


@pytest.fixture(scope="module")
def D48():
    rng = np.random.default_rng(7)
    return euclidean_distance_matrix(rng.normal(size=(48, 4)))


@pytest.fixture(scope="module")
def D50():
    # NOT divisible by any mesh size -> exercises the padding path
    rng = np.random.default_rng(8)
    return euclidean_distance_matrix(rng.normal(size=(50, 4)))


@pytest.mark.parametrize("strategy", ["allgather", "ring"])
def test_1d_strategies(D48, strategy):
    mesh = meshlib.make_test_mesh((8,), ("data",))
    C = np.asarray(distributed.pald_distributed(D48, mesh, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,axes", [
    ((4, 2), ("data", "model")),
    ((2, 4), ("data", "model")),
    ((2, 2, 2), ("pod", "data", "model")),
])
def test_2d_strategy(D48, shape, axes):
    mesh = meshlib.make_test_mesh(shape, axes)
    C = np.asarray(distributed.pald_distributed(D48, mesh, strategy="2d", impl="jnp"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


def test_2d_pod_stream_equals_full_gather(D48):
    """The hierarchical pod-streamed schedule must be numerically identical
    to the plain 2-D schedule (it only changes data movement)."""
    mesh = meshlib.make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    C1 = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", pod_stream=False, impl="jnp"))
    C2 = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", pod_stream=True, impl="jnp"))
    np.testing.assert_allclose(C2, _ref(D48), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(C1, C2, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("strategy", ["ring", "2d"])
def test_padding_path(D50, strategy):
    mesh = (meshlib.make_test_mesh((8,), ("data",)) if strategy == "ring"
            else meshlib.make_test_mesh((4, 2), ("data", "model")))
    C = np.asarray(distributed.pald_distributed(D50, mesh, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, _ref(D50), rtol=1e-5, atol=1e-6)


def test_interpret_kernels_under_shard_map(D48):
    """Per-device compute routed through the Pallas kernels (interpret)."""
    mesh = meshlib.make_test_mesh((2, 2), ("data", "model"))
    C = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", impl="interpret"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


def test_bf16_comm_dtype(D48):
    """bf16 distance communication (§Perf 3): exact whenever no two
    distances collide in the same bf16 ulp (generic random data)."""
    import jax.numpy as jnp
    mesh = meshlib.make_test_mesh((4, 2), ("data", "model"))
    C = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", impl="jnp", comm_dtype=jnp.bfloat16))
    # bf16 rounding perturbs the order of near-equal distances only; on
    # generic data the cohesion matrix stays close to fp32
    assert np.abs(C - _ref(D48)).max() < 5e-3
    assert abs(C.sum() - 24.0) < 0.1   # mass ~ n/2 preserved


def test_auto_strategy(D48):
    mesh1 = meshlib.make_test_mesh((8,), ("data",))
    mesh2 = meshlib.make_test_mesh((4, 2), ("data", "model"))
    for mesh in (mesh1, mesh2):
        C = np.asarray(distributed.pald_distributed(D48, mesh, impl="jnp"))
        np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# feature-sharded strategies: X row-sharded, distances derived on-device
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def X50():
    rng = np.random.default_rng(9)
    return rng.normal(size=(50, 4)).astype(np.float32)  # 50: padding path


@pytest.mark.parametrize("strategy", ["allgather", "ring"])
@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_from_features_strategies(X50, strategy, metric):
    from repro.core import features, pald

    mesh = meshlib.make_test_mesh((8,), ("data",))
    Cref = np.asarray(pald.cohesion(
        features.cdist_reference(X50, metric=metric), method="dense"))
    C = np.asarray(distributed.pald_distributed_from_features(
        jnp.asarray(X50), mesh, metric=metric, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_from_features_multi_axis_mesh_flattens(X50):
    from repro.core import features, pald

    mesh = meshlib.make_test_mesh((4, 2), ("data", "model"))
    Cref = np.asarray(pald.cohesion(
        features.cdist_reference(X50, metric="euclidean"), method="dense"))
    C = np.asarray(distributed.pald_distributed_from_features(
        jnp.asarray(X50), mesh, impl="jnp"))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_from_features_rejects_unknown_strategy(X50):
    mesh = meshlib.make_test_mesh((8,), ("data",))
    with pytest.raises(ValueError):
        distributed.pald_distributed_from_features(
            jnp.asarray(X50), mesh, strategy="2d")


# ---------------------------------------------------------------------------
# coverage gap: 2d at degenerate/asymmetric pr != pc splits
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,axes", [
    ((8, 1), ("data", "model")),   # all rows, trivial column axis
    ((1, 8), ("data", "model")),   # trivial row axis, all columns
    ((4, 2, 1), ("pod", "data", "model")),  # pr=8 (two row axes), pc=1
])
def test_2d_strategy_asymmetric(D50, shape, axes):
    """pr != pc splits, including the degenerate pr=1 / pc=1 edges, on the
    padding-exercising n=50 matrix."""
    mesh = meshlib.make_test_mesh(shape, axes)
    C = np.asarray(distributed.pald_distributed(
        D50, mesh, strategy="2d", impl="jnp"))
    np.testing.assert_allclose(C, _ref(D50), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# dryrun_pald: sharded-knn comm estimates must match the n*d claim
# ---------------------------------------------------------------------------
def test_dryrun_knn_comm_matches_nd_claim():
    """core/distributed_knn docstring: every strategy moves O(n*d) feature
    words per device, never the O(n^2) distance matrix; ring pays exactly
    twice allgather (two rotations); 2d adds only the O((n/pr)*k)
    selection-merge term on top of its feature movement."""
    from repro.launch.dryrun_pald import knn_shard_estimate

    n, d, k = 100_000, 64, 32
    for p in (8, 64, 256):
        ag = knn_shard_estimate(n, d, k, strategy="allgather", pr=1, pc=p)
        ring = knn_shard_estimate(n, d, k, strategy="ring", pr=1, pc=p)
        wa = ag["comm"]["per_device_words"]
        wr = ring["comm"]["per_device_words"]
        assert wa == (p - 1) * (-(-n // p)) * d    # (p-1)/p * n*d exactly
        assert wa < n * d                          # never a full n*d copy
        assert wr == 2 * wa                        # two ring rotations
        assert wa * p < n * n                      # and NEVER O(n^2) total

    for pr, pc in ((16, 16), (32, 8), (2, 128)):
        p = pr * pc
        est = knn_shard_estimate(n, d, k, strategy="2d", pr=pr, pc=pc)
        bd = est["comm"]["breakdown"]
        mloc, mr = -(-n // p), -(-n // pr)
        feature_words = bd["allgather_x"] + bd["rowcand_slabs"]
        assert feature_words <= 2 * n * d          # still O(n*d) features
        kt = min(k, pr * mloc)
        assert bd["merge_partials"] == 2 * (pc - 1) * mr * kt
        # the n*d claim is about FEATURE movement; the merge term is the
        # 2d strategy's selection overhead and blows up on degenerate
        # splits (tiny pr, huge pc) — the model must expose that honestly
        if pr >= pc:
            assert est["comm"]["per_device_words"] * p < n * n
        else:
            assert bd["merge_partials"] > feature_words


def test_dryrun_knn_estimate_cell_shape():
    from repro.launch.dryrun_pald import knn_shard_estimate

    cell = knn_shard_estimate(10_000, 16, 8, strategy="ring", pr=1, pc=16)
    assert cell["status"] == "ok" and cell["chips"] == 16
    t = cell["roofline"]
    assert t["bottleneck"] in ("compute", "collective")
    assert t["compute_s"] > 0 and t["collective_s"] > 0
    assert cell["comm"]["strategy"] == "ring"
