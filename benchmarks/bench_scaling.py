"""Paper Figs. 9-11 analogue: parallel scaling of distributed PaLD.

Two parts:

1. MEASURED strong/weak scaling on this host's fake CPU devices (1..8):
   wall-clock of ``pald_distributed`` per strategy.  CPU "devices" are
   threads, so these speedups are indicative, not roofline.

2. MODELED communication volume per chip on the production meshes, the
   TPU analogue of the paper's NUMA study: allgather vs ring vs 2-D vs
   2-D+pod-stream on (16,16) and (2,16,16).  The 2-D schedule is the
   comm-optimal one (Θ(n²/√P) words/chip); pod-streaming keeps every word
   crossing the slow inter-pod link exactly once.
"""
from __future__ import annotations

import functools

import numpy as np

import jax

from repro.core import distributed
from repro.launch import mesh as meshlib

from .common import emit, random_distance_matrix, time_fn


def measured(n: int = 768) -> list[dict]:
    D = random_distance_matrix(n)
    rows = []
    ndev = len(jax.devices())
    for p in (1, 2, 4, 8):
        if p > ndev:
            break
        mesh = meshlib.make_test_mesh((p,), ("data",))
        for strat in ("allgather", "ring"):
            t = time_fn(functools.partial(
                distributed.pald_distributed, D, mesh,
                strategy=strat, impl="jnp"), warmup=1, iters=2)
            rows.append({"kind": "strong", "strategy": strat, "p": p, "n": n,
                         "seconds": round(t, 4)})
        if p >= 2:
            r = int(p ** 0.5) if int(p ** 0.5) ** 2 == p else None
            shape = (r, r) if r else (p // 2, 2)
            mesh2 = meshlib.make_test_mesh(shape, ("data", "model"))
            t = time_fn(functools.partial(
                distributed.pald_distributed, D, mesh2,
                strategy="2d", impl="jnp"), warmup=1, iters=2)
            rows.append({"kind": "strong", "strategy": "2d", "p": p, "n": n,
                         "seconds": round(t, 4)})
    # weak scaling: n^3/p fixed  ->  n scales as p^(1/3)
    n1 = 512
    for p in (1, 2, 4, 8):
        if p > ndev:
            break
        nw = int(n1 * p ** (1 / 3) // 16 * 16)
        Dw = random_distance_matrix(nw, seed=p)
        mesh = meshlib.make_test_mesh((p,), ("data",))
        t = time_fn(functools.partial(
            distributed.pald_distributed, Dw, mesh,
            strategy="ring", impl="jnp"), warmup=1, iters=2)
        rows.append({"kind": "weak", "strategy": "ring", "p": p, "n": nw,
                     "seconds": round(t, 4)})
    return rows


def comm_model(n: int = 100_000) -> list[dict]:
    """Per-chip words moved by each strategy (fp32 words)."""
    rows = []
    for mesh_name, (pods, pr, pc) in [("16x16", (1, 16, 16)),
                                      ("2x16x16", (2, 16, 16))]:
        P = pods * pr * pc
        rows += [
            {"mesh": mesh_name, "strategy": "allgather",
             # gather all of D onto every chip
             "words_per_chip": int(n * n * (1 - 1 / P)),
             "peak_mem_words": n * n},
            {"mesh": mesh_name, "strategy": "ring",
             # rotate row blocks P-1 times (both passes)
             "words_per_chip": int(2 * n * (n / P) * (P - 1)),
             "peak_mem_words": int(2 * n * n / P)},
            {"mesh": mesh_name, "strategy": "2d",
             # gather row block along cols + col slab along rows, both passes
             "words_per_chip": int(2 * (n * n / (pods * pr) + n * n / pc)),
             "peak_mem_words": int(n * n / pc + n * n / (pods * pr))},
            {"mesh": mesh_name, "strategy": "2d+pod-stream",
             # intra-pod gathers + one inter-pod traversal of the slab
             "words_per_chip": int(2 * (n * n / (pods * pr) + n * n / pc)),
             "peak_mem_words": int(n * n / pc / pods + n * n / (pods * pr)),
             },
        ]
    for r in rows:
        r["GB_per_chip"] = round(r["words_per_chip"] * 4 / 1e9, 2)
        r["peak_GB"] = round(r["peak_mem_words"] * 4 / 1e9, 2)
    return rows


def main() -> None:
    emit(measured(), header="fig10/11: measured scaling (fake CPU devices)")
    emit(comm_model(), header="fig9 analogue: modeled comm volume, n=100k")


if __name__ == "__main__":
    main()
