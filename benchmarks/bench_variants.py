"""Paper Table 1 analogue: pairwise vs triplet running time across n.

The paper's crossover (pairwise wins small-n, triplet wins large-n thanks to
~2x fewer comparisons) shows up here as dense vs block-symmetric.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.core import pairwise, triplet

from .common import emit, random_distance_matrix, time_fn


def run(ns=(128, 256, 512, 1024, 2048)) -> list[dict]:
    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b = min(256, n)
        tp = time_fn(functools.partial(pairwise.pald_blocked, D, block=b))
        tt = time_fn(functools.partial(triplet.pald_block_symmetric, D, block=b))
        rows.append({
            "n": n,
            "pairwise_s": round(tp, 4),
            "triplet_s": round(tt, 4),
            "triplet_speedup": round(tp / tt, 3),
        })
    return rows


def main() -> None:
    emit(run(), header="table1: pairwise vs triplet")


if __name__ == "__main__":
    main()
