import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed for the
single-pod (16, 16) and multi-pod (2, 16, 16) production meshes, for every
runnable cell.  Per cell we record memory_analysis(), cost_analysis() and
the collective schedule parsed from the optimized HLO, dumped as JSON for
benchmarks/roofline.py.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/dryrun_out
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.base import SHAPES, runnable
from repro.launch import hlo_analysis, mesh as meshlib, specs


def _extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        # roofline uses modeled link traffic; operand bytes kept alongside
        "coll_bytes": float(coll.total_traffic),
        "coll_operand_bytes": float(coll.total_bytes),
        "coll": coll.as_dict(),
    }


def probe_costs(cfg, shape, mesh, *, microbatches: int = 1,
                q_chunk: int = 1024) -> dict:
    """Per-chip (flops, bytes, collective bytes) via unrolled probes.

    XLA cost analysis counts a while-loop body ONCE, so the production
    program (scan over layer repeats, lax.map over q chunks) under-reports
    everything by ~depth×.  We compile the same cell at 1 and 2
    layer-repeats with every loop python-unrolled (identical math and
    chunk structure, no while ops), then extrapolate linearly:

        cost(R) = cost(1) + (R - 1) * (cost(2) - cost(1))

    This is exact for costs that are affine in depth (all of ours: the
    top-level embed/head/loss/optimizer is the intercept, the layer body is
    the slope).
    """
    R = cfg.n_repeats
    out = {}
    probes = {}
    for r in (1, 2):
        pcfg = dataclasses.replace(
            cfg, n_layers=r * len(cfg.pattern), scan_unroll=r,
            probe_unroll=True,
        )
        fn, args = specs.cell_lowerable(
            pcfg, shape, mesh, q_chunk=q_chunk, microbatches=microbatches
        )
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
        probes[r] = _extract_costs(compiled)
    for k in ("flops", "bytes", "coll_bytes"):
        # a tiny negative slope can appear on shallow decode cells (XLA
        # optimizes the 1- and 2-repeat programs slightly differently);
        # clamp — per-layer cost is physically non-negative
        slope = max(probes[2][k] - probes[1][k], 0.0)
        out[k] = probes[1][k] + (R - 1) * slope
        out[k + "_per_layer_repeat"] = slope
    out["coll_by_kind_2repeat"] = probes[2]["coll"]["by_kind"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             q_chunk: int = 1024, microbatches: int = 1,
             verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    ok, why = runnable(cfg, shape)
    if shape.kind == "train" and microbatches == 1:
        microbatches = cfg.train_microbatches
    cell = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "microbatches": microbatches,
    }
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell

    t0 = time.time()
    fn, args = specs.cell_lowerable(
        cfg, shape, mesh, q_chunk=q_chunk, microbatches=microbatches
    )
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw = _extract_costs(compiled)

    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)

    # while-loop bodies are counted once by cost analysis -> probe-compile
    # unrolled 1/2-repeat variants and extrapolate to the real depth
    t0 = time.time()
    # probes always run microbatches=1: the mb loop is a while (counted
    # once); the step's total compute is batch-size-, not mb-, determined.
    # Grad all-reduces differ slightly (once per mb vs once) — noted in
    # EXPERIMENTS.md.
    probed = probe_costs(cfg, shape, mesh, microbatches=1, q_chunk=q_chunk)
    t_probe = time.time() - t0

    terms = hlo_analysis.roofline_terms(
        hlo_flops=probed["flops"], hlo_bytes=probed["bytes"],
        coll_bytes=probed["coll_bytes"], chips=chips,
        flops_is_global=False,  # partitioned executable = per-chip program
    )
    mf = hlo_analysis.model_flops(cfg, shape)
    cell.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        probe_s=round(t_probe, 2),
        memory_analysis=mem_d,
        hlo_flops_per_chip=probed["flops"],
        hlo_bytes_per_chip=probed["bytes"],
        coll_bytes_per_chip=probed["coll_bytes"],
        per_layer_repeat={
            k: probed[k + "_per_layer_repeat"] for k in ("flops", "bytes", "coll_bytes")
        },
        coll_by_kind_2repeat=probed["coll_by_kind_2repeat"],
        raw_while_counted_once=raw,
        roofline=terms,
        model_flops_global=mf,
        model_flops_per_chip=mf / chips,
        useful_flop_ratio=(mf / chips / probed["flops"]) if probed["flops"] else None,
    )
    if verbose:
        ma = mem_d.get("temp_size_in_bytes", 0) + mem_d.get("argument_size_in_bytes", 0)
        print(
            f"  ok  lower {t_lower:5.1f}s compile {t_compile:6.1f}s probe {t_probe:6.1f}s  "
            f"bytes/dev {ma/2**30:7.2f} GiB  "
            f"flops/chip {probed['flops']:,.3g}  "
            f"coll {probed['coll_bytes']/2**20:,.1f} MiB  "
            f"bottleneck {terms['bottleneck']}  "
            f"useful {cell['useful_flop_ratio'] and round(cell['useful_flop_ratio'], 3)}"
        )
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_out")
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    archs = list(configs.ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                print(f"[dryrun] {tag}")
                try:
                    cell = run_cell(
                        arch, shape, multi,
                        q_chunk=args.q_chunk, microbatches=args.microbatches,
                    )
                except Exception:
                    failures += 1
                    cell = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "error",
                        "traceback": traceback.format_exc(limit=12),
                    }
                    print("  ERROR")
                    print(cell["traceback"])
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(cell, f, indent=1)
    print(f"[dryrun] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
