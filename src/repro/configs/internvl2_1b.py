"""internvl2-1b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT + InternLM2/Qwen2-0.5B-style LM backbone; the vision frontend is a
stub providing precomputed patch embeddings (per brief).
[arXiv:2404.16821; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    modality="vlm",
    sharding_profile="fsdp",
    remat="full",
    subquadratic=False,
)
