"""Paper §7 re-created: semantic communities in embedding space, at scale,
with the distributed pipeline — and wired into the LM framework: the
"embeddings" here are rows of a trained checkpoint's token-embedding table
(or synthetic stand-ins when you haven't trained one yet).

    PYTHONPATH=src python examples/pald_text_analysis.py [--ckpt DIR]

This is PaLD as a first-class analysis feature of the training framework:
point it at a checkpoint and it reports which token neighborhoods have
formed strong relative-distance communities.
"""
import argparse

import numpy as np

import jax

from repro.core import analysis, distributed
from repro.launch import mesh as meshlib


def embeddings_from_checkpoint(ckpt_dir: str, max_tokens: int) -> np.ndarray:
    from repro.checkpoint import checkpointer
    steps = checkpointer.available_steps(ckpt_dir)
    if not steps:
        raise SystemExit(f"no checkpoints under {ckpt_dir}")
    import os, json
    path = os.path.join(ckpt_dir, f"step_{steps[-1]:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    key = next(k for k in man["leaves"] if k.endswith("embed/embedding"))
    emb = np.load(os.path.join(path, man["leaves"][key]["file"]))
    return emb[:max_tokens].astype(np.float32)


def synthetic_vocabulary(n: int = 2712, dim: int = 64) -> np.ndarray:
    rng = np.random.default_rng(7)
    topics = rng.normal(size=(48, dim)) * 4
    out = []
    for i in range(n):
        t = i % 48
        spread = 0.2 + (t % 5) * 0.35     # topic density varies 8x
        out.append(topics[t] + rng.normal(size=dim) * spread)
    return np.asarray(out, np.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--max-tokens", type=int, default=2712)
    args = ap.parse_args()

    X = (embeddings_from_checkpoint(args.ckpt, args.max_tokens)
         if args.ckpt else synthetic_vocabulary(args.max_tokens))
    n = X.shape[0]
    D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    print(f"[pald-text] n={n} embedding_dim={X.shape[1]}")

    ndev = len(jax.devices())
    mesh = meshlib.make_test_mesh((ndev,), ("data",))
    import time
    t0 = time.perf_counter()
    C = np.asarray(distributed.pald_distributed(D, mesh, strategy="ring", impl="jnp"))
    print(f"[pald-text] distributed cohesion on {ndev} devices: "
          f"{time.perf_counter()-t0:.2f}s")

    tau = analysis.universal_threshold(C)
    comms = analysis.communities(C)
    big = [c for c in comms if len(c) > 1]
    print(f"[pald-text] tau={tau:.5f}  communities>1: {len(big)}  "
          f"sizes: {sorted((len(c) for c in big), reverse=True)[:10]} ...")

    # the paper's word-cloud: strongest ties of a couple of probe tokens
    for probe in (0, n // 2):
        ties = analysis.top_ties(C, probe, k=8)
        shown = ", ".join(f"tok{i}:{v:.4f}" for i, v in ties if v > tau)
        print(f"[pald-text] strong ties of tok{probe}: {shown or '(none)'}")


if __name__ == "__main__":
    main()
