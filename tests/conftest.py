"""Test configuration.

Forces a small pool of host devices (8, NOT the dry-run's 512) before the
first jax import so the shard_map / pjit tests have a real multi-device mesh
to run on.  Single-device tests are unaffected — they just see 8 CPU
devices and use the first.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def euclidean_distance_matrix(X: np.ndarray) -> np.ndarray:
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return D


@pytest.fixture
def small_D(rng):
    """A generic (tie-free w.h.p.) 37-point Euclidean distance matrix."""
    X = rng.normal(size=(37, 5))
    return euclidean_distance_matrix(X)


@pytest.fixture
def clustered_D(rng):
    """Two well-separated clusters of different scales (PaLD's home turf)."""
    a = rng.normal(size=(12, 3)) * 0.5
    b = rng.normal(size=(20, 3)) * 3.0 + 40.0
    return euclidean_distance_matrix(np.vstack([a, b]))
