"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) d_ff=512(per-expert)
vocab=49155, MoE 32 experts top-8 every layer.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=49155,
    head_dim=64,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    # group_tokens=128: with 512-wide experts the dispatch einsums rival
    # expert FLOPs at the default 512 groups (§Perf bonus iteration:
    # -15% compute, -9% collective, useful 0.344 -> 0.406)
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, group_tokens=128),
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=2,
    subquadratic=False,
)
