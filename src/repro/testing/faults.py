"""Fault-injection harness for the guarded-execution layer.

Context managers that arm the named fault points threaded through the
engine dispatch, the kernel entry points and the feature front-end
(``repro.core.resilience.fault_point``), plus tuning-cache corruption and
locking helpers.  Each manager yields the armed ``FaultRule`` so a test
can assert on ``rule.trips`` afterwards; disarming is exception-safe.

    from repro.testing import faults

    with faults.failing("engine.execute"):
        pald.cohesion(D, on_error="fallback")       # chain rescues it

    with faults.fail_kernel(impl="interpret", nth=2):
        ...                                          # 2nd kernel call dies

    with faults.simulate_oom(max_batch=2):
        plan.execute(Db)                             # halves batch to 2

    with faults.corrupt_tuning_cache(path):
        pald.plan(n=256)                             # quarantine, not crash

Injection sites (substring-matched): ``engine.execute`` (primary dispatch,
strict and fallback modes), ``engine.batch`` (the chunked-vmap layer, with
``batch=`` context for OOM predicates), ``ops.focus_general`` /
``ops.cohesion_general`` / ``ops.pald_tri`` / ``ops.pald_fused`` /
``ops.knn_values`` (kernel entry points, with the *resolved* ``impl=`` so
rules can target one backend), ``features.cdist`` (the materialize-D
front-end) and ``resilience.step`` (each degradation-chain rung).
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import Callable, Iterator

from repro.core import resilience as _res
from repro.core.resilience import FaultRule, simulated_oom

__all__ = [
    "failing",
    "fail_kernel",
    "simulate_oom",
    "corrupt_tuning_cache",
    "locked_tuning_cache",
    "reset",
    "write_cache",
]


def reset() -> None:
    """Fresh harness state: disarm every rule, forget warn-once keys."""
    with _res._RULES_LOCK:
        _res._RULES.clear()
    _res.reset_warnings()


@contextlib.contextmanager
def failing(
    site: str = "",
    *,
    exc: Callable[[], BaseException] | None = None,
    match: dict | None = None,
    pred: Callable[..., bool] | None = None,
    nth: int = 1,
    times: int | None = None,
) -> Iterator[FaultRule]:
    """Arm one generic fault rule for the ``with`` body.

    ``site`` substring-matches the fault-point name ("" = every site);
    ``match`` requires exact equality on context kwargs (e.g.
    ``impl="interpret"``); ``pred`` is an arbitrary predicate over
    ``(site=..., **ctx)``; ``nth`` is the 1-based matching call at which
    tripping starts; ``times`` caps the number of trips (None = every
    matching call).  ``exc`` is a zero-arg exception factory (default: a
    RuntimeError naming the site).
    """
    if exc is None:
        def exc(s=site):  # noqa: E731 - default factory names the site
            return RuntimeError(f"injected fault at {s or '<any site>'}")
    rule = _res.arm(FaultRule(exc=exc, site=site, match=match, pred=pred,
                              nth=nth, times=times))
    try:
        yield rule
    finally:
        _res.disarm(rule)


@contextlib.contextmanager
def fail_kernel(
    impl: str | None = None,
    *,
    nth: int = 1,
    times: int | None = None,
    exc: Callable[[], BaseException] | None = None,
) -> Iterator[FaultRule]:
    """Make the Nth kernel entry-point call raise.

    Matches every ``ops.*`` fault point; ``impl=`` narrows to one backend
    — the sites report the *resolved* impl, so ``impl="pallas"`` faults
    exactly the calls a real Pallas lowering failure would kill while the
    interpret/jnp fallback attempts run clean.
    """
    match = None if impl is None else {"impl": impl}
    with failing("ops.", exc=exc, match=match, nth=nth, times=times) as rule:
        yield rule


@contextlib.contextmanager
def simulate_oom(
    site: str = "engine.batch",
    *,
    max_batch: int | None = None,
    nth: int = 1,
    times: int | None = None,
) -> Iterator[FaultRule]:
    """Raise a ``RESOURCE_EXHAUSTED``-shaped error at ``site``.

    With ``max_batch=``, only batched calls whose chunk bound exceeds it
    trip — modelling a device that fits ``max_batch`` items: the guard's
    halving retry then converges to a batch the "device" accepts, instead
    of failing forever.
    """
    pred = None
    if max_batch is not None:
        def pred(site, batch=None, **ctx):  # noqa: A002 - fault-point ctx
            return batch is not None and batch > max_batch
    with failing(site, exc=simulated_oom, pred=pred, nth=nth,
                 times=times) as rule:
        yield rule


@contextlib.contextmanager
def corrupt_tuning_cache(
    path: str | None = None,
    garbage: str = '{"tpu|pallas|1024|pald": {"block": 256, "bl',
) -> Iterator[str]:
    """Replace the tuning cache file with garbled bytes for the body.

    The default garbage is a truncated JSON object — the realistic
    kill-the-writer corruption.  The original file (if any) is restored on
    exit, the quarantine sidecars the body produced are removed, and the
    in-memory memo is invalidated both ways so the corruption is actually
    observed.  Yields the cache path.
    """
    from repro.tuning import autotune as _tuner

    p = os.path.abspath(_tuner.cache_path(path))
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    original = None
    if os.path.exists(p):
        with open(p) as f:
            original = f.read()
    with open(p, "w") as f:
        f.write(garbage)
    _tuner._MEM.pop(p, None)
    try:
        yield p
    finally:
        _tuner._MEM.pop(p, None)
        _tuner._QUARANTINE_WARNED.discard(p)
        for name in os.listdir(os.path.dirname(p)):
            full = os.path.join(os.path.dirname(p), name)
            if full.startswith(p + ".corrupt-"):
                os.remove(full)
        if original is None:
            if os.path.exists(p):
                os.remove(p)
        else:
            with open(p, "w") as f:
                f.write(original)


@contextlib.contextmanager
def locked_tuning_cache(path: str | None = None) -> Iterator[str]:
    """Hold the exclusive ``save_entry`` lock for the ``with`` body.

    A concurrent ``save_entry`` on the same cache must wait (or, past its
    ``lock_timeout``, warn and write unlocked) — the harness side of the
    two-writer race tests.  No-op yield on platforms without fcntl.
    """
    from repro.tuning import autotune as _tuner

    p = os.path.abspath(_tuner.cache_path(path))
    if _tuner.fcntl is None:  # pragma: no cover - non-POSIX platform
        yield p
        return
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p + ".lock", "w") as lf:
        _tuner.fcntl.flock(lf, _tuner.fcntl.LOCK_EX)
        try:
            yield p
        finally:
            _tuner.fcntl.flock(lf, _tuner.fcntl.LOCK_UN)


def write_cache(path: str, records: dict) -> str:
    """Write a well-formed cache file (test fixture helper)."""
    p = os.path.abspath(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
    return p
