"""Execution-plan engine: resolve once, run anywhere.

The paper's speedups come from picking the right variant per problem size
(blocked pairwise vs. block-symmetric triplet vs. tuned kernel tiles), but
that choice used to be re-derived in four places: ``core/pald.py`` branched
on method, every ``kernels/ops`` entry point re-resolved blocks/impl/padding,
``core/features.py`` had its own batch layer, and ``core/distributed.py``
re-threaded impl+ties into every shard body.  This module centralizes ALL of
that (DESIGN.md §11):

``plan(x, kind=...) -> PaldPlan``
    Performs every resolution exactly once — auto-method via the tuning
    cache, ``block="auto"`` via ``tuning.resolve_blocks``, impl defaults per
    pipeline, knob validation (``schedule="tri"`` off-kernel, ``block_z`` on
    a non-kernel path, ``z_chunk`` off-dense, ...), and input shape/value
    checks — and returns a frozen, reusable plan.

``PaldPlan.execute(x)``
    The single dispatch path: looks the resolved ``(kind, method, schedule)``
    up in the EXECUTOR REGISTRY and runs it.  Batched input (``(B, n, n)``
    distances or ``(B, n, d)`` features) is handled here, once, for every
    method — chunked ``jax.vmap`` bounded by the plan's ``batch=`` knob —
    so the Pallas tri pipeline batches exactly like the dense jnp paths.

``register_executor(kind, method, schedule)``
    How ``core/pairwise``, ``core/triplet`` and ``kernels/ops`` contribute
    their callables; alternative backends (a partitioned-kNN local depth, a
    generalized-PaLD variant) plug in the same way without touching the
    facades.

``PaldPlan.explain()``
    The resolved dict — method/tiles with cache provenance, padded shape,
    estimated VMEM per grid step — for debuggability and bench provenance.

``pald.cohesion`` / ``pald.from_features`` are thin facades over
``plan(...).execute(x)``; they contain no method branching.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.tuning import autotune as _tuner

from . import resilience as _res
from .weights import (DEFAULT_TIES, WeightFunctional, registered_weights,
                      resolve_weight, validate_ties)

__all__ = [
    "PaldPlan",
    "plan",
    "plan_local",
    "register_executor",
    "get_executor",
    "available_executors",
    "pad_distance_matrix",
    "run_batched",
]

DISTANCE_METHODS = ("dense", "pairwise", "triplet", "kernel", "knn")
FEATURE_METHODS = ("fused",) + DISTANCE_METHODS
SCHEDULES = ("dense", "tri")

# methods whose executors take an impl= knob (kernel pipelines); the pure-jnp
# blocked paths have exactly one implementation, so an explicit impl request
# there is a caller error, not something to drop silently
_IMPL_METHODS = ("kernel", "fused", "knn")


def pad_distance_matrix(
    D: jnp.ndarray, block: int, *, dtype=jnp.float32
) -> tuple[jnp.ndarray, int]:
    """Pad D to a multiple of ``block`` with +inf off-diagonal, 0 diagonal.

    Padded points are infinitely far from everything: they never enter a real
    pair's local focus (inf < d is false) and every real z is inside a padded
    pair's focus but contributes to padded rows of C only.

    The input is cast to ``dtype`` (float32 by default) *here*, before any
    blocked arithmetic — this is the pipeline's one explicit downcast point;
    nothing downstream changes precision again.
    """
    D = jnp.asarray(D, dtype)
    n = D.shape[0]
    m = -(-n // block) * block
    if m == n:
        return D, n
    P = jnp.full((m, m), jnp.inf, D.dtype)
    P = P.at[:n, :n].set(D)
    P = P.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    return P, n


# ---------------------------------------------------------------------------
# executor registry
# ---------------------------------------------------------------------------
_EXECUTORS: dict[tuple[str, str, str], Callable] = {}


def register_executor(kind: str, method: str, schedule: str = "dense"):
    """Decorator: contribute the executor for one (kind, method, schedule)
    cell.  The callable receives ``(x, plan)`` with ``x`` one UNBATCHED item
    (a (n, n) distance matrix or (n, d) feature matrix, any float dtype) and
    owns the full per-item pipeline: cast, pad, compute, slice, normalize.
    It must be traceable (plan.execute vmaps it for batched input)."""

    def deco(fn):
        _EXECUTORS[(kind, method, schedule)] = fn
        return fn

    return deco


def _load_contributors() -> None:
    """Import the modules that register the default executors.  Deferred so
    importing the engine (or core.pald) stays cheap and cycle-free; the
    kernels package in particular is only pulled in on first kernel use."""
    from repro.core import pairwise, triplet  # noqa: F401
    from repro.kernels import ops  # noqa: F401


def get_executor(kind: str, method: str, schedule: str) -> Callable:
    key = (kind, method, schedule)
    if key not in _EXECUTORS:
        _load_contributors()
    if key not in _EXECUTORS:
        raise KeyError(
            f"no executor registered for {key}; known cells: "
            f"{sorted(_EXECUTORS)}"
        )
    return _EXECUTORS[key]


def available_executors() -> list[tuple[str, str, str]]:
    """All registered (kind, method, schedule) cells (contributors loaded)."""
    _load_contributors()
    return sorted(_EXECUTORS)


def run_batched(fn, x, plan: "PaldPlan", batch: int | None = None):
    """The engine's uniform batch layer: run executor ``fn`` over ``x``.

    2-D input goes straight through; 3-D input is vmapped in chunks of
    ``batch`` items (None = the whole batch in one compiled call).
    Chunking is a pure re-partition of the same computation — results are
    bitwise-equal for any chunk size (asserted in test_conformance.py),
    which is what makes the OOM batch-halving retry in ``core/resilience``
    a value-preserving degradation.

    Shared by ``PaldPlan.execute`` and the degradation-chain steps so a
    fallback attempt batches exactly like the primary attempt did.
    """
    if x.ndim == 2:
        return fn(x, plan)
    B = x.shape[0]
    eff = B if batch is None else min(batch, B)
    _res.fault_point("engine.batch", batch=eff, n=plan.n, kind=plan.kind,
                     method=plan.method, impl=plan.impl)
    single = lambda xi: fn(xi, plan)  # noqa: E731
    if eff >= B:
        return jax.vmap(single)(x)
    chunks = [jax.vmap(single)(x[s:s + eff]) for s in range(0, B, eff)]
    return jnp.concatenate(chunks, axis=0)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PaldPlan:
    """Frozen result of one resolution pass: everything an executor needs.

    Build with ``plan(...)`` (or ``plan_local`` for distributed shard
    bodies); never mutate — a plan is safe to reuse across calls and across
    threads for any input matching its item shape.
    """

    kind: str                     # "distance" | "features"
    method: str                   # resolved (never "auto")
    schedule: str                 # "dense" | "tri"
    impl: str | None              # kernel/fused impl; None = one-impl path
    block: int | None             # None for the un-blocked dense method
    block_z: int | None           # z tile; None = executor default
    z_chunk: int | None           # dense-method z streaming chunk
    ties: str
    metric: str | None            # features kind only
    normalize: bool
    batch: int | None             # vmap chunk bound for batched input
    check: bool                   # deep input validation on execute
    n: int                        # per-item point count
    d: int | None                 # feature dimension (features kind)
    k: int | None = None          # neighborhood size (knn method only)
    on_error: str = "raise"       # "raise" | "fallback" (degradation chain)
    # knn selection stage (features kind): impl override and its tiles.
    # select=None follows impl; "chunked" is the terminal degradation rung
    # (row-chunked lax.top_k).  select_tile >= n disables the tile-min
    # prefilter (direct slab top_k); see kernels/ops.topk_select.
    select: str | None = None
    select_block: int | None = None   # rows per selection slab
    select_tile: int | None = None    # tile-min prefilter width
    select_source: str = "n/a"        # provenance (explain)
    # mesh-sharded knn (features kind, core/distributed_knn.py): the device
    # mesh the fused select->cohere pipeline shards over, and the resolved
    # shard strategy ('allgather'/'ring'/'2d').  None = single device.
    mesh: Any = None
    strategy: str | None = None
    # the resolved weight functional (core/weights.py); ``ties`` above is its
    # name, kept as the stable string surface for explain()/fault contexts.
    weight: WeightFunctional | None = None
    # provenance (explain)
    method_source: str = "explicit"
    block_source: str = "explicit"
    # structured degradation events appended by core/resilience when
    # on_error="fallback" degrades an execution; surfaced in explain().
    # init=False keeps the frozen plan hashable/replace()-safe: derived
    # plans start with a fresh empty log while the guard records on the
    # plan the caller holds.
    _events: list = dataclasses.field(
        default_factory=list, init=False, compare=False, repr=False)

    # -- execution ---------------------------------------------------------
    def execute(self, x) -> jnp.ndarray:
        """Run the planned pipeline on ``x`` — one item or a batch.

        ``x``: (n, n) / (B, n, n) distances, or (n, d) / (B, n, d) features,
        matching the plan's item shape.  Batching is uniform across every
        (method, schedule) cell: items are vmapped in chunks of ``batch=``
        (None = whole batch in one compiled call), which bounds peak memory
        at ``batch * n^2`` floats regardless of the underlying executor.

        With ``on_error="fallback"`` a failing execution degrades instead
        of raising: OOM on the batched call retries with halved ``batch``
        (re-chunking is bitwise-equal), any other executor failure walks
        the cell's degradation chain (``core/resilience``) re-executing
        with identical ties/normalize semantics.  Every degradation is
        recorded in ``explain()["degradations"]``.
        """
        x = jnp.asarray(x)
        _check_input(x, self)
        if self.on_error == "fallback":
            return _res.execute_plan(self, x)
        _res.fault_point("engine.execute", kind=self.kind, method=self.method,
                         schedule=self.schedule, impl=self.impl)
        fn = get_executor(self.kind, self.method, self.schedule)
        return run_batched(fn, x, self, self.batch)

    # -- distributed shard-body primitives ---------------------------------
    # The shard bodies in core/distributed.py call the rectangular kernel
    # forms per step; threading the plan instead of four loose knobs keeps
    # the resolution in one place (and in explain()).
    def focus_general(self, DXZ, DYZ, DXY) -> jnp.ndarray:
        from repro.kernels import ops as _kops

        def call(impl):
            return _kops.focus_general(DXZ, DYZ, DXY, block=self.block,
                                       block_z=self.block_z, impl=impl,
                                       ties=self.weight)

        if self.on_error == "fallback":
            return _res.guarded_general(self, "focus_general", call)
        return call(self.impl)

    def cohesion_general(self, DXZ, DYZ, DXY, W, *, xwins=None,
                         xw_offsets=None) -> jnp.ndarray:
        from repro.kernels import ops as _kops

        def call(impl):
            return _kops.cohesion_general(DXZ, DYZ, DXY, W, block=self.block,
                                          block_z=self.block_z, impl=impl,
                                          ties=self.weight, xwins=xwins,
                                          xw_offsets=xw_offsets)

        if self.on_error == "fallback":
            return _res.guarded_general(self, "cohesion_general", call)
        return call(self.impl)

    # -- introspection -----------------------------------------------------
    @property
    def padded_n(self) -> int:
        """Per-item extent after the engine-level pad to a block multiple
        (the kernel pipelines may pad further for their z tiles)."""
        if self.block is None:
            return self.n
        return -(-self.n // self.block) * self.block

    def _shard_rows(self) -> int | None:
        """Per-shard padded row count of a mesh plan (None off the mesh)."""
        if self.mesh is None:
            return None
        from repro.core import distributed_knn as _dknn

        p = self.mesh.devices.size
        chunk = self.select_block or 1
        _, _, m = _dknn.resolve_shard_shapes(self.n, p=p, chunk=chunk)
        return m // p

    def _comm_estimate(self) -> dict | None:
        """Per-device comm model of a mesh plan (None off the mesh)."""
        if self.mesh is None:
            return None
        from repro.core import distributed_knn as _dknn

        import math as _math
        shape = tuple(self.mesh.devices.shape)
        p = self.mesh.devices.size
        pr = _math.prod(shape[:-1]) if len(shape) >= 2 else 1
        return _dknn.comm_estimate(
            self.strategy or "auto", n=self.n, d=self.d or 1,
            k=self.k or 1, p=p, pr=pr, pc=shape[-1])

    def explain(self) -> dict[str, Any]:
        """The resolved plan as a plain dict — the debuggability surface.

        Returns:
            Dict with STABLE keys (bench provenance rows and debug logs
            rely on them): the resolved ``kind`` / ``method`` /
            ``schedule`` / ``impl`` / ``block`` / ``block_z`` /
            ``z_chunk`` / ``ties`` / ``weight`` / ``weight_properties`` /
            ``metric`` / ``normalize`` /
            ``batch`` / ``n`` / ``d`` / ``k`` / ``on_error`` (plus
            ``degradations``, the guarded-execution event log), the knn
            selection-stage report ``select`` / ``select_block`` /
            ``select_tile`` / ``select_source`` (None / "n/a" off the
            knn method), the mesh-sharding report ``mesh`` /
            ``mesh_axes`` / ``strategy`` / ``shard_rows`` /
            ``comm_estimate`` (device-mesh shape, resolved strategy,
            per-shard padded rows and the per-device communication model
            of ``core/distributed_knn.py``; all None off the mesh), the
            ``padded_n`` /
            ``padded_shape`` the executor will see, ``method_source`` and
            ``block_source`` provenance strings ("explicit",
            "cache:<key>", "nearest:<key>", "default", ...), the
            fully-qualified ``executor`` callable, and
            ``est_vmem_bytes_per_step`` (a planning aid, not a promise).

        Example:
            >>> from repro.core import pald
            >>> info = pald.plan(n=256, method="triplet", block=64).explain()
            >>> info["method"], info["block"], info["padded_n"]
            ('triplet', 64, 256)
        """
        fn = get_executor(self.kind, self.method, self.schedule)
        return {
            "kind": self.kind,
            "method": self.method,
            "schedule": self.schedule,
            "impl": self.impl,
            "block": self.block,
            "block_z": self.block_z,
            "z_chunk": self.z_chunk,
            "ties": self.ties,
            "weight": self.weight.name if self.weight else self.ties,
            "weight_properties": (self.weight.properties()
                                  if self.weight else None),
            "metric": self.metric,
            "normalize": self.normalize,
            "batch": self.batch,
            "n": self.n,
            "d": self.d,
            "k": self.k,
            "padded_n": self.padded_n,
            "padded_shape": ((self.padded_n, self.padded_n)
                             if self.kind == "distance"
                             else (self.padded_n, self.d)),
            "on_error": self.on_error,
            "select": self.select,
            "select_block": self.select_block,
            "select_tile": self.select_tile,
            "select_source": self.select_source,
            "mesh": (tuple(self.mesh.devices.shape)
                     if self.mesh is not None else None),
            "mesh_axes": (tuple(self.mesh.axis_names)
                          if self.mesh is not None else None),
            "strategy": self.strategy,
            "shard_rows": self._shard_rows(),
            "comm_estimate": self._comm_estimate(),
            "method_source": self.method_source,
            "block_source": self.block_source,
            "executor": f"{fn.__module__}.{fn.__qualname__}",
            "est_vmem_bytes_per_step": _est_vmem_per_step(self),
            # structured degradation events recorded by guarded execution
            # (on_error="fallback"): dicts with cell / cause / error /
            # fallback / retries, in occurrence order.  Empty on a plan
            # that never degraded.
            "degradations": list(self._events),
        }


def _est_vmem_per_step(p: PaldPlan) -> int | None:
    """Rough f32 bytes resident per grid step (per fori step for the jnp
    paths).  A planning aid — tile residency of the dominant pass-2 body,
    not a promise about XLA's actual allocation."""
    if p.block is None:  # un-blocked dense: (n, n, z_chunk) comparison cube
        zc = p.z_chunk or p.n
        return 4 * p.n * p.n * zc
    b = p.block
    m = p.padded_n
    if p.method == "knn":
        # (b, k, k) gathered tile + (b, k, k) comparison cube + (b, k) rows
        kk = p.k or 1
        est = 4 * (2 * b * kk * kk + 3 * b * kk + b * (kk + 1))
        if p.kind == "features" and p.select_block:
            # fused select->cohere: one (select_block, n) distance slab
            # is live per map step alongside the cohesion tiles
            est += 4 * p.select_block * p.n
        return est
    if p.method in ("pairwise", "triplet"):
        # (b, b, n) support cube + two (b, n) row slabs
        return 4 * (b * b * m + 2 * b * m)
    bz = p.block_z or min(512, m)
    d_ = p.d or 0
    tiles = 2 * b * bz + 2 * b * b + b * bz        # dxz, dyz, dxy, w, out
    if p.method == "fused":
        tiles += 2 * b * max(d_, 1)                # feature tiles
    if p.schedule == "tri":
        tiles += m * bz                            # resident Cy column slab
    return 4 * tiles


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------
def _item_shape_checks(x, p: PaldPlan) -> None:
    if x.ndim not in (2, 3):
        what = ("D must be (n, n) or (B, n, n)" if p.kind == "distance"
                else "X must be (n, d) or (B, n, d)")
        raise ValueError(f"{what}, got shape {tuple(x.shape)}")
    if p.kind == "distance" and x.shape[-1] != x.shape[-2]:
        raise ValueError(
            f"distance matrix must be square, got shape {tuple(x.shape)}")
    expect = (p.n, p.n) if p.kind == "distance" else (p.n, p.d)
    if tuple(x.shape[-2:]) != expect:
        raise ValueError(
            f"input item shape {tuple(x.shape[-2:])} does not match the "
            f"plan's {expect}; build a new plan for a new problem size")


def _check_input(x, p: PaldPlan) -> None:
    """Cheap always-on checks plus the opt-in deep ones (``check=True``).

    Value checks only run on concrete arrays — under jit/vmap tracing the
    values don't exist yet, and shape checks are all that can (and need to)
    fire there.  Note the flip side: an eager call on a device array that a
    previous async computation is still producing must SYNC on the O(n)
    diagonal fetch before dispatching, costing host-side overlap (never
    correctness).  A latency-critical pipeline that wants fully async
    dispatch should wrap the call in ``jax.jit`` — traced execution skips
    the value checks by construction.
    """
    _item_shape_checks(x, p)
    if isinstance(x, jax.core.Tracer) or p.kind != "distance":
        if p.check and not isinstance(x, jax.core.Tracer):
            if not bool(jnp.isfinite(x).all()):
                raise ValueError("features contain non-finite entries "
                                 "(nan/inf); PaLD needs finite coordinates")
        return
    # always-on O(n) check: a nonzero (or nan) diagonal means the input is
    # not a self-distance matrix — every padding and focus invariant assumes
    # d(x, x) == 0
    diag = np.asarray(jnp.diagonal(x, axis1=-2, axis2=-1))
    if not np.all(diag == 0.0):
        raise ValueError(
            "distance matrix diagonal must be exactly 0 "
            f"(got max |diag| = {np.nanmax(np.abs(diag))!r}; nan counts as "
            "nonzero); pass distances with d(x, x) = 0")
    if not p.check:
        return
    xv = np.asarray(x)
    if not np.isfinite(xv).all():
        raise ValueError("distance matrix contains non-finite entries "
                         "(nan/inf)")
    if (xv < 0).any():
        raise ValueError("distance matrix contains negative entries; "
                         "PaLD consumes the order of nonnegative distances")
    if not np.array_equal(xv, np.swapaxes(xv, -1, -2)):
        raise ValueError("distance matrix is not symmetric (exact equality "
                         "is required: PaLD compares d_xz against d_zx's "
                         "role symmetrically)")


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def _shape_of(x, n, d, kind):
    if x is not None:
        shape = tuple(np.shape(x))
        if len(shape) not in (2, 3):
            what = ("D must be (n, n) or (B, n, n)" if kind == "distance"
                    else "X must be (n, d) or (B, n, d)")
            raise ValueError(f"{what}, got shape {shape}")
        item = shape[-2:]
        if kind == "distance":
            if item[0] != item[1]:
                raise ValueError(
                    f"distance matrix must be square, got shape {shape}")
            return item[0], None
        return item[0], item[1]
    if n is None:
        raise ValueError("plan() needs either an input array or n=")
    if kind == "features" and d is None:
        raise ValueError("plan(kind='features') needs d= when no array "
                         "is given")
    return int(n), None if kind == "distance" else int(d)


def _resolve_weight_knob(ties, weight) -> WeightFunctional:
    """Resolve the ``ties=``/``weight=`` knob pair to ONE functional.

    ``ties=`` is sugar for the three built-in modes; ``weight=`` accepts any
    registered name or ``WeightFunctional`` instance.  Both given and
    resolving to different functionals is a contradiction (rejected, like
    every other knob pair); both None means the default (``'drop'``).
    """
    if weight is None:
        if ties is None:
            return resolve_weight(DEFAULT_TIES)
        validate_ties(ties)
        return resolve_weight(ties)
    w = resolve_weight(weight)
    if ties is not None:
        validate_ties(ties)
        tie_name = getattr(ties, "name", ties)
        if tie_name != w.name:
            raise ValueError(
                f"contradictory ties={tie_name!r} and weight={w.name!r}; "
                "ties= is sugar for the built-in modes — drop it, or pass "
                f"the matching one (registered weight functionals: "
                f"{registered_weights()})")
    return w


def _default_kernel_impl(method: str) -> str:
    """Backend-default impl per pipeline (mirrors kernels/ops): the fused
    and knn paths prefer the vectorized jnp fallback off-TPU (they exist
    for large n, where interpret-mode kernel emulation is prohibitive),
    the D-consuming kernel pipeline prefers bit-faithful interpret
    execution."""
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        return "pallas"
    return "jnp" if method in ("fused", "knn") else "interpret"


def plan(
    x=None,
    *,
    kind: str = "distance",
    n: int | None = None,
    d: int | None = None,
    method: str = "auto",
    schedule: str = "dense",
    block: int | str | None = None,
    block_z: int | str | None = None,
    z_chunk: int | None = None,
    metric: str | None = None,
    normalize: bool = True,
    impl: str | None = None,
    ties: str | None = None,
    weight=None,
    batch: int | None = None,
    check: bool = False,
    k: int | None = None,
    on_error: str = "raise",
    select: str | None = None,
    select_block: int | str | None = None,
    select_tile: int | str | None = None,
    mesh=None,
    strategy: str | None = None,
) -> PaldPlan:
    """Resolve every knob exactly once and return a frozen ``PaldPlan``.

    ``x`` (or ``n=``/``d=``) fixes the per-item problem size the resolution
    is keyed on.  ``kind`` selects the input contract: ``"distance"`` (a
    precomputed (n, n) matrix — ``pald.cohesion``) or ``"features"`` ((n, d)
    vectors — ``pald.from_features``).  All remaining knobs have the same
    meaning as on the facades; validation rejects contradictions instead of
    silently dropping knobs (``schedule='tri'`` off the kernel pipeline,
    ``block_z``/``impl`` on a path that has no such degree of freedom,
    ``z_chunk`` off the dense method, unknown metrics/methods/tie modes,
    contradictory ``ties=``/``weight=``).
    ``ties=`` is sugar for the three built-in weight functionals;
    ``weight=`` accepts any registered functional name or
    ``WeightFunctional`` instance (``core/weights.py``) and generalizes the
    contribution algebra on every cell with zero kernel forks.
    ``on_error`` selects the failure semantics: ``"raise"`` (default)
    propagates the first executor failure unchanged, ``"fallback"`` walks
    the cell's degradation chain (``core/resilience``) and records every
    degradation in ``explain()["degradations"]``.
    ``select=`` / ``select_block=`` / ``select_tile=`` configure the knn
    SELECTION stage (features kind): the impl of the streaming top-k
    ('pallas'/'interpret'/'jnp'/'chunked'; None follows ``impl``), the
    rows per selection slab, and the tile-min prefilter width (>= n
    disables it); "auto"/None resolve via the ``pald_topk:k<k>:d<d>``
    tuning-cache pass.  On kind='distance' only ``select='chunked'`` (the
    row-chunked ``lax.top_k`` terminal rung) is meaningful.
    ``mesh=`` / ``strategy=`` shard the fused select->cohere knn pipeline
    across a ``jax.sharding.Mesh`` (``core/distributed_knn.py``): rows of X
    are sharded over all mesh axes and features rotate by ``strategy``
    ('allgather', 'ring', or '2d'; 'auto'/None picks '2d' on a >= 2-axis
    mesh, 'ring' otherwise).  Only kind='features' with method='knn'
    accepts a mesh, the result stays bitwise-equal to the single-device
    fused path, and ``explain()`` reports the mesh shape, per-shard rows,
    and a per-device comm estimate.

    One deliberate exception: ``block=`` is accepted AND ignored by
    ``method='dense'`` (the un-blocked path has no tile), so the common
    "sweep every method with one shared block argument" idiom stays valid —
    ``explain()['block']`` is ``None`` there, making the drop visible.
    """
    weight = _resolve_weight_knob(ties, weight)
    ties = weight.name
    if kind not in ("distance", "features"):
        raise ValueError(f"unknown kind {kind!r} "
                         "(expected 'distance' or 'features')")
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}")
    if on_error not in _res.ON_ERROR_MODES:
        raise ValueError(f"unknown on_error {on_error!r} (expected one of "
                         f"{_res.ON_ERROR_MODES}): 'raise' propagates the "
                         "first executor failure, 'fallback' walks the "
                         "degradation chain")
    n, d = _shape_of(x, n, d, kind)
    if batch is not None and batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")

    if kind == "features":
        from .features import METRICS

        metric = metric or "euclidean"
        if metric not in METRICS:
            raise ValueError(
                f"unknown metric {metric!r} (expected one of {METRICS})")
        allowed = FEATURE_METHODS
    else:
        if metric is not None:
            raise ValueError("metric= only applies to kind='features' "
                             "(a distance matrix already fixed it)")
        allowed = DISTANCE_METHODS

    # -- method ------------------------------------------------------------
    # Path-specific knobs PIN the auto method (the way an explicit tri
    # schedule always has) instead of letting the tuning cache decide and
    # then validating against its answer — otherwise whether a knob is legal
    # would flip with the input size and with another machine's cache state.
    method_source = "explicit"
    if method == "auto":
        if schedule == "tri":
            # an explicit tri request pins the kernel pipeline (the only
            # method with a tri schedule)
            method, method_source = "kernel", "schedule=tri"
        elif k is not None:
            # a neighborhood size is a knn request on either kind — the
            # sparse approximation must be opted into, never auto-selected
            if z_chunk is not None:
                raise ValueError(
                    "k= pins method='knn' but z_chunk= pins method='dense'; "
                    "pass an explicit method")
            method, method_source = "knn", "k"
        elif kind == "features":
            method, method_source = "fused", "default"
        elif z_chunk is not None:
            if impl is not None or block_z not in (None, "auto"):
                raise ValueError(
                    "z_chunk= pins method='dense' but impl=/block_z= pin "
                    "the kernel pipeline; pass an explicit method")
            method, method_source = "dense", "z_chunk"
        elif impl is not None or block_z not in (None, "auto"):
            # an explicit z TILE (or impl) is a kernel-pipeline request;
            # block_z="auto" is not — "auto" means "pick for me", which on a
            # path without a z tile legitimately resolves to "no tile", so
            # it must not override the measured method crossover
            method, method_source = "kernel", "impl/block_z"
        else:
            method, method_source = _tuner.method_for_ex(n)
    if method not in allowed:
        raise ValueError(f"unknown method {method!r} for kind={kind!r} "
                         f"(expected one of {('auto',) + allowed})")
    if schedule == "tri" and method != "kernel":
        raise ValueError(
            f"schedule='tri' is only available for method='kernel' (the "
            f"Pallas upper-triangular pipeline), got method={method!r}; "
            f"pass method='kernel' or drop schedule=")

    # -- neighborhood size (knn only) ---------------------------------------
    if method == "knn":
        if k is None:
            raise ValueError(
                "method='knn' needs k= (neighborhood size, 1 <= k <= n-1); "
                "at k = n-1 the result equals the dense methods exactly")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        k = min(int(k), max(n - 1, 0))
    elif k is not None:
        raise ValueError(
            f"k= is only valid with method='knn' (got method={method!r}); "
            "the dense/pairwise/triplet/kernel paths always rank every "
            "point against every other — drop k=, or pass method='knn'")

    # -- selection stage (knn only) -----------------------------------------
    if method != "knn" and (select is not None or select_block is not None
                            or select_tile is not None):
        raise ValueError(
            "select=/select_block=/select_tile= configure the knn neighbor "
            f"selection stage (got method={method!r}); drop them, or pass "
            "method='knn'")
    if select not in (None, "pallas", "interpret", "jnp", "chunked"):
        raise ValueError(
            f"unknown select {select!r} (expected 'pallas', 'interpret', "
            "'jnp' or 'chunked')")
    if kind == "distance" and select not in (None, "chunked"):
        raise ValueError(
            f"select={select!r} needs kind='features' (the streaming "
            "selection impls consume feature tiles); on a distance matrix "
            "only the row-chunked rung select='chunked' applies")
    if kind == "distance" and (select_block is not None
                               or select_tile is not None):
        raise ValueError(
            "select_block=/select_tile= only apply to kind='features' "
            "(they tile the feature-space selection slabs)")

    # -- mesh sharding (features knn only) ----------------------------------
    if strategy is not None and mesh is None:
        raise ValueError(
            f"strategy={strategy!r} configures the mesh-sharded knn "
            "pipeline; pass mesh= (a jax.sharding.Mesh) alongside it")
    if mesh is not None:
        from . import distributed_knn as _dknn

        if kind != "features" or method != "knn":
            raise ValueError(
                "mesh= shards the fused select->cohere knn pipeline and "
                f"needs kind='features' with method='knn' (got kind={kind!r}"
                f", method={method!r}); drop mesh=, or pass k= to request "
                "the knn method on feature input")
        if batch is not None:
            raise ValueError(
                "mesh= plans run one item at a time (the device mesh is the "
                "parallel axis); drop batch=")
        if strategy is not None and strategy not in _dknn.STRATEGIES:
            raise ValueError(
                f"unknown strategy {strategy!r} (expected one of "
                f"{_dknn.STRATEGIES})")
        axes = tuple(mesh.axis_names)
        if strategy in (None, "auto"):
            strategy = "2d" if len(axes) >= 2 else "ring"
        if strategy == "2d" and len(axes) < 2:
            raise ValueError(
                "strategy='2d' needs a mesh with >= 2 axes (row x column "
                f"split), got axes={axes}; use 'ring' or 'allgather'")

    # -- impl --------------------------------------------------------------
    if method in _IMPL_METHODS:
        impl = impl or _default_kernel_impl(method)
    elif impl is not None:
        # silently dropping an explicit request would let a caller believe
        # it exercised a path it didn't
        raise ValueError(
            f"impl={impl!r} is only configurable for the kernel/fused/knn "
            f"pipelines; method={method!r} has exactly one implementation")

    # -- per-method knob surface -------------------------------------------
    if z_chunk is not None and method != "dense":
        raise ValueError(
            f"z_chunk= only applies to method='dense' (the blocked paths "
            f"stream z by block_z tiles), got method={method!r}; drop "
            f"z_chunk= or pass method='dense'")
    if method == "dense":
        if block_z not in (None, "auto"):
            raise ValueError("block_z= does not apply to method='dense' "
                             "(it has no z tile; use z_chunk=)")
        return PaldPlan(
            kind=kind, method=method, schedule=schedule, impl=None,
            block=None, block_z=None, z_chunk=z_chunk, ties=ties,
            weight=weight,
            metric=metric, normalize=normalize, batch=batch, check=check,
            n=n, d=d, on_error=on_error, method_source=method_source,
            block_source="n/a",
        )
    if method in ("pairwise", "triplet"):
        if block_z not in (None, "auto"):
            raise ValueError(
                f"block_z= does not apply to method={method!r} (the "
                "pure-jnp blocked paths stream the full z axis per block "
                "pair)")
        # block_z="auto" resolves to "no z tile" here — a valid resolution,
        # not a dropped knob; explain() shows block_z=None with no z
        # provenance, and no tuning-cache scan is wasted on it
        block_z = None
    if method == "knn":
        if block_z not in (None, "auto"):
            raise ValueError(
                "block_z= does not apply to method='knn' (the third axis "
                "is the k neighbors themselves); tune block=, the row tile")
        block_z = None

    # -- tiles -------------------------------------------------------------
    block_source = "explicit"
    if block is None:
        block = "auto" if method in ("fused", "knn") else 128
        block_source = "default"
    if method == "knn":
        if block == "auto":
            block, _, src = _tuner.resolve_blocks_ex(
                n, "pald_knn", ties=weight, k=k, impl=impl)
            block_source = src
        block = max(min(int(block), max(n, 1)), 1)
        sel_source = "n/a"
        sb = st = None
        if kind == "features":
            # selection-stage tiles resolve once here (pald_topk pass) so
            # the executor never consults the cache and explain() reports
            # the exact slab/tile the fused select->cohere will run
            sb = "auto" if select_block is None else select_block
            st = "auto" if select_tile is None else select_tile
            sel_source = "explicit"
            if sb == "auto" or st == "auto":
                rb, rt, sel_source = _tuner.resolve_blocks_ex(
                    n, "pald_topk", d=d, k=k, impl=(select or impl),
                    p=(int(mesh.devices.size) if mesh is not None else None))
                sb = rb if sb == "auto" else sb
                st = rt if st == "auto" else st
            sb = max(min(int(sb), max(n, 1)), 1)
            st = max(min(int(st), max(n, 1)), 1)
        return PaldPlan(
            kind=kind, method=method, schedule=schedule, impl=impl,
            block=block, block_z=None, z_chunk=None, ties=ties,
            weight=weight,
            metric=metric, normalize=normalize, batch=batch, check=check,
            n=n, d=d, k=k, on_error=on_error, method_source=method_source,
            block_source=block_source, select=select, select_block=sb,
            select_tile=st, select_source=sel_source,
            mesh=mesh, strategy=(strategy if mesh is not None else None),
        )
    if method == "fused":
        # one authority for the fused tile defaults, shared with
        # kernels/ops.pald_fused (tuning.resolve_fused_tiles) — the plan can
        # never drift from what the kernel entry point would compute
        was_auto = block == "auto"
        block, block_z, src = _tuner.resolve_fused_tiles(
            n, d, block, block_z, impl=impl, ties=weight)
        if src is not None:
            # provenance tracks the *block* tile; an explicit block with an
            # auto block_z must not claim the user's tile came from the cache
            block_source = src if was_auto else f"{block_source}; z:{src}"
    elif block == "auto" or block_z == "auto":
        pass_ = "pald_tri" if schedule == "tri" else "pald"
        rb, rbz, src = _tuner.resolve_blocks_ex(n, pass_, ties=weight)
        block_source = src if block == "auto" else f"{block_source}; z:{src}"
        block = rb if block == "auto" else block
        if method == "kernel" and block_z in (None, "auto"):
            block_z = rbz
    block = int(block)
    block_z = None if block_z is None else int(block_z)

    return PaldPlan(
        kind=kind, method=method, schedule=schedule, impl=impl,
        block=block, block_z=block_z, z_chunk=None, ties=ties,
        weight=weight,
        metric=metric, normalize=normalize, batch=batch, check=check,
        n=n, d=d, on_error=on_error, method_source=method_source,
        block_source=block_source,
    )


def plan_local(
    n: int,
    *,
    impl: str | None = None,
    ties: str | None = None,
    weight=None,
    block: int | str = "auto",
    block_z: int | str = "auto",
    on_error: str = "raise",
) -> PaldPlan:
    """Plan for the rectangular per-device bodies of ``core/distributed``.

    ``n`` is the per-device row extent the tiles are keyed on.  The shard
    bodies consume the plan through ``plan.focus_general`` /
    ``plan.cohesion_general``; ``impl=None`` keeps the kernels' own backend
    default (jnp off-TPU — the vectorized fallback, which is what the
    collectives overlap against).
    """
    weight = _resolve_weight_knob(ties, weight)
    ties = weight.name
    if on_error not in _res.ON_ERROR_MODES:
        raise ValueError(f"unknown on_error {on_error!r} (expected one of "
                         f"{_res.ON_ERROR_MODES})")
    block_source = "explicit"
    if block == "auto" or block_z == "auto":
        rb, rbz, src = _tuner.resolve_blocks_ex(max(int(n), 1), "cohesion",
                                                impl=impl)
        block = rb if block == "auto" else block
        block_z = rbz if block_z == "auto" else block_z
        block_source = src
    return PaldPlan(
        kind="distance", method="kernel", schedule="dense", impl=impl,
        block=int(block), block_z=int(block_z), z_chunk=None, ties=ties,
        weight=weight,
        metric=None, normalize=False, batch=None, check=False,
        n=max(int(n), 1), d=None, on_error=on_error,
        method_source="shard-body", block_source=block_source,
    )


# ---------------------------------------------------------------------------
# built-in executors: the features->materialized-D compositions.  The fused
# path and all distance paths are contributed by their home modules; these
# cells are pure composition, so they live with the registry.
# ---------------------------------------------------------------------------
def _materialize_then(schedule: str):
    def _exec(X, p: PaldPlan):
        from .features import cdist_reference

        D = cdist_reference(X, metric=p.metric)
        return get_executor("distance", p.method, schedule)(D, p)

    return _exec


for _m in DISTANCE_METHODS:
    if _m != "knn":  # features-knn never materializes D; kernels/ops owns it
        register_executor("features", _m, "dense")(_materialize_then("dense"))
register_executor("features", "kernel", "tri")(_materialize_then("tri"))
del _m
