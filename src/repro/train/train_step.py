"""Train-step factory: loss, grads, AdamW update — pjit-ready.

Mixed precision: fp32 master params (sharded per profile — ZeRO over the
data axes), bf16 compute copy cast inside the loss, fp32 softmax/loss.
Optional gradient accumulation (``microbatches > 1``) scans over micro
slices of the global batch, trading stash memory for steps — how the
biggest assigned config (jamba-398B) fits v5e HBM.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model, cast_floats
from repro.optim import adamw

Array = jnp.ndarray


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Gather-free cross entropy.

    ``take_along_axis`` over the vocab dim is a gather along a
    tensor-sharded axis — GSPMD replies with an all-gather of the full
    (B, S, V) logits per device (measured: 37 GiB/chip at train_4k).  The
    masked-sum form is elementwise + reduction, so the vocab shard layout
    from the LM-head einsum flows straight through the loss.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot_mask = (
        jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        == labels[..., None]
    )
    gold = jnp.sum(jnp.where(onehot_mask, logits, 0.0), axis=-1)
    return (logz - gold).mean()


def make_loss_fn(cfg: ModelConfig, *, q_chunk: int = 512):
    model = Model(cfg)

    def loss_fn(params, batch):
        p = cast_floats(params, jnp.bfloat16)
        if "embeds" in batch:
            b = {"embeds": batch["embeds"].astype(jnp.bfloat16)}
        else:
            b = {"tokens": batch["tokens"]}
        logits, aux = model.apply(p, b, q_chunk=q_chunk)
        loss = cross_entropy(logits, batch["labels"])
        return loss + aux, (loss, aux)

    return loss_fn


def init_state(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (state, logical spec tree mirroring it)."""
    model = Model(cfg)
    params, pspecs = model.init(key)
    opt = adamw.init(params)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    specs = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs},
        "step": (),
    }
    return state, specs


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
    *,
    microbatches: int = 1,
    q_chunk: int = 512,
):
    loss_fn = make_loss_fn(cfg, q_chunk=q_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if microbatches == 1:
            (tot, (loss, aux)), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum, asum = carry
                (tot, (l, a)), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l, asum + a), None

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(()), jnp.zeros(())), mbs
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, aux = loss / microbatches, aux / microbatches

        new_p, new_opt, metrics = adamw.apply(
            opt_cfg, params, grads, state["opt"], state["step"]
        )
        new_state = {"params": new_p, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, "aux": aux, **metrics}

    return train_step
