"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

Nothing in this module allocates device memory: model/optimizer state comes
from ``jax.eval_shape`` and inputs are ShapeDtypeStructs with NamedShardings
attached, so ``jax.jit(...).lower(...)`` can compile 512-chip programs on a
single-CPU host.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.sharding import partition
from repro.train import serve_step, train_step

SDS = jax.ShapeDtypeStruct


def _with_shardings(shapes: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: SDS(s.shape, s.dtype, sharding=sh), shapes, shardings
    )


def _cast_tree(shapes: Any, dtype) -> Any:
    def c(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return SDS(s.shape, dtype)
        return s
    return jax.tree.map(c, shapes)


# ---------------------------------------------------------------------------
# model / optimizer state
# ---------------------------------------------------------------------------
def state_specs(cfg: ModelConfig, mesh: Mesh):
    """(state ShapeDtypeStructs with shardings, logical spec tree)."""
    key = SDS((2,), jnp.uint32)
    cap: dict[str, Any] = {}

    def build(k):
        state, specs = train_step.init_state(cfg, k)
        cap["specs"] = specs  # pure-static string tree; capture, don't trace
        return state

    shapes = jax.eval_shape(build, key)
    specs = cap["specs"]
    psh = partition.param_shardings(
        specs["params"], cfg.sharding_profile, mesh, shapes["params"]
    )
    shardings = {
        "params": psh,
        "opt": {"m": psh, "v": psh},
        "step": NamedSharding(mesh, P()),
    }
    return _with_shardings(shapes, shardings), shardings


def param_specs(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    """Serving-time parameter stand-ins (bf16 on-device copies)."""
    key = SDS((2,), jnp.uint32)
    model = Model(cfg)
    cap: dict[str, Any] = {}

    def build(k):
        params, specs = model.init(k)
        cap["specs"] = specs
        return params

    shapes = jax.eval_shape(build, key)
    shardings = partition.param_shardings(
        cap["specs"], cfg.sharding_profile, mesh, shapes
    )
    return _with_shardings(_cast_tree(shapes, dtype), shardings), shardings


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    model = Model(cfg)
    shapes = jax.eval_shape(
        lambda: model.init_caches(batch, max_len, dtype=jnp.bfloat16)
    )
    shardings = serve_step.cache_shardings(cfg, mesh, batch, max_len)
    return _with_shardings(shapes, shardings), shardings


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Input stand-ins for one step of the given shape kind.

    train    {"tokens"|"embeds", "labels"}: full (B, S) sequences
    prefill  {"tokens"|"embeds"}: the prompt batch
    decode   one new token (B, 1) (or (B, 1, d) embeds)
    """
    B, S = shape.global_batch, shape.seq_len
    bspec = partition.batch_pspec(mesh, B)
    tok_sh = NamedSharding(mesh, P(*bspec, None))
    emb_sh = NamedSharding(mesh, P(*bspec, None, None))
    stub = cfg.modality in ("audio", "vlm")

    def toks(s):
        return SDS((B, s), jnp.int32, sharding=tok_sh)

    def embs(s):
        return SDS((B, s, cfg.d_model), jnp.bfloat16, sharding=emb_sh)

    if shape.kind == "train":
        batch = {"embeds": embs(S)} if stub else {"tokens": toks(S)}
        batch["labels"] = toks(S)
        return batch
    if shape.kind == "prefill":
        return {"embeds": embs(S)} if stub else {"tokens": toks(S)}
    # decode: one token against a cache of S slots
    return {"embeds": embs(1)} if stub else {"tokens": toks(1)}


def cell_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                   q_chunk: int = 1024, microbatches: int = 1):
    """(fn, example_args) ready for ``jax.jit(fn).lower(*example_args)``."""
    if shape.kind == "train":
        step = train_step.make_train_step(
            cfg, microbatches=microbatches, q_chunk=q_chunk
        )
        state, _ = state_specs(cfg, mesh)
        return step, (state, batch_specs(cfg, shape, mesh))
    if shape.kind == "prefill":
        step = serve_step.make_prefill_step(cfg, q_chunk=q_chunk)
        params, _ = param_specs(cfg, mesh)
        caches, _ = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        return step, (params, batch_specs(cfg, shape, mesh), caches)
    # decode
    step = serve_step.make_decode_step(cfg)
    params, _ = param_specs(cfg, mesh)
    caches, _ = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    batch = batch_specs(cfg, shape, mesh)
    token = batch.get("tokens", batch.get("embeds"))
    pos = SDS((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return step, (params, token, caches, pos)
