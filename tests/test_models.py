"""Per-architecture smoke tests on reduced same-family configs (CPU-sized)
+ model-level consistency checks (prefill/decode vs full forward)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import reduced
from repro.models.model import Model
from repro.train.train_step import init_state, make_train_step


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_train(arch, key):
    """One forward + one train step for the reduced config of every assigned
    architecture: output shapes, no NaNs, finite loss."""
    cfg = reduced(configs.get(arch))
    model = Model(cfg)
    params, _ = model.init(key)
    B, S = 2, 16
    if cfg.modality in ("audio", "vlm"):
        batch = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model)) * 0.02,
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
        logits, aux = model.apply(params, {"embeds": batch["embeds"]})
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        logits, aux = model.apply(params, {"tokens": toks})
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits[..., : cfg.vocab_size])).any()

    step = make_train_step(cfg)
    state, _ = init_state(cfg, key)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_serve(arch, key):
    """Prefill + 3 decode steps for every architecture."""
    cfg = reduced(configs.get(arch))
    model = Model(cfg)
    params, _ = model.init(key)
    B, S = 2, 8
    caches = model.init_caches(B, S + 4)
    if cfg.modality in ("audio", "vlm"):
        emb = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        logits, caches = model.prefill(params, {"embeds": emb}, caches)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        logits, caches = model.prefill(params, {"tokens": toks}, caches)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits[..., : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    for i in range(3):
        if cfg.modality in ("audio", "vlm"):
            step_in = jax.random.normal(key, (B, 1, cfg.d_model)) * 0.02
        else:
            step_in = tok
        logits, caches = model.decode_step(params, step_in, caches,
                                           jnp.asarray(S + i, jnp.int32))
        assert not np.isnan(np.asarray(logits[..., : cfg.vocab_size])).any()
        tok = jnp.argmax(logits[..., : cfg.vocab_size], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma2-2b", "mamba2-780m",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch, key):
    """Token-by-token decode must reproduce the full-sequence forward logits
    (KV-cache / SSM-state correctness)."""
    cfg = reduced(configs.get(arch))
    model = Model(cfg)
    params, _ = model.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)

    full_logits, _ = model.apply(params, {"tokens": toks})  # (B, S, V)

    caches = model.init_caches(B, S, dtype=jnp.float32)
    step_logits = []
    for i in range(S):
        lg, caches = model.decode_step(params, toks[:, i:i+1], caches,
                                       jnp.asarray(i, jnp.int32))
        step_logits.append(lg)
    dec = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-2, atol=2e-3
    )


def test_prefill_then_decode_matches_forward(key):
    cfg = reduced(configs.get("llama3.2-3b"))
    model = Model(cfg)
    params, _ = model.init(key)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = model.apply(params, {"tokens": toks})

    caches = model.init_caches(B, S, dtype=jnp.float32)
    last, caches = model.prefill(params, {"tokens": toks[:, :-1]}, caches)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, -2]), rtol=2e-2, atol=2e-3
    )
    lg, _ = model.decode_step(params, toks[:, -1:], caches,
                              jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, -1]), rtol=2e-2, atol=2e-3
    )


def test_sliding_window_masks_old_tokens(key):
    """A windowed layer must ignore tokens beyond the window."""
    base = reduced(configs.get("gemma2-2b"))
    model = Model(base)
    params, _ = model.init(key)
    B, S, W = 1, 16, 4  # reduced gemma pattern: window=4096 >> S, so craft one
    import dataclasses
    from repro.configs.base import LayerSpec
    cfg = dataclasses.replace(
        base,
        pattern=(LayerSpec(mixer="attn", ffn="dense", window=W),
                 LayerSpec(mixer="attn", ffn="dense", window=None)),
    )
    model = Model(cfg)
    params, _ = model.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    logits, _ = model.apply(params, {"tokens": toks})
    # perturbing a token further back than every window+global layer can
    # reach changes nothing ONLY if all layers are windowed; with a global
    # layer logits do change — sanity-check the mask plumbing by comparing a
    # pure-windowed stack instead
    cfg_w = dataclasses.replace(
        base, n_layers=2,
        pattern=(LayerSpec(mixer="attn", ffn="dense", window=W),),
    )
    model_w = Model(cfg_w)
    params_w, _ = model_w.init(key)
    lg1, _ = model_w.apply(params_w, {"tokens": toks})
    toks2 = toks.at[:, 0].set((toks[:, 0] + 7) % cfg.vocab_size)
    lg2, _ = model_w.apply(params_w, {"tokens": toks2})
    # with 2 stacked window-4 layers, position 15 sees back to ~position 9;
    # position 0 is far outside — its perturbation must not leak
    np.testing.assert_allclose(
        np.asarray(lg1[:, -1, : cfg.vocab_size]),
        np.asarray(lg2[:, -1, : cfg.vocab_size]),
        rtol=1e-5, atol=1e-6,
    )
    # ...but it must leak into nearby positions
    assert not np.allclose(
        np.asarray(lg1[:, 1, : cfg.vocab_size]),
        np.asarray(lg2[:, 1, : cfg.vocab_size]),
    )


def test_moe_load_balance_aux_positive(key):
    cfg = reduced(configs.get("phi3.5-moe-42b-a6.6b"))
    model = Model(cfg)
    params, _ = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, aux = model.apply(params, {"tokens": toks})
    assert float(aux) > 0.0


def test_param_counts_match_sizes():
    expect = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
        "granite-moe-1b-a400m": (1.3e9, 0.4e9),
        "mamba2-780m": (0.78e9, 0.78e9),
        "qwen2.5-14b": (14.8e9, 14.8e9),
        "llama3.2-3b": (3.2e9, 3.2e9),
        "gemma2-2b": (2.6e9, 2.6e9),
        "gemma2-9b": (9.2e9, 9.2e9),
        "jamba-1.5-large-398b": (398e9, 94e9),
        "musicgen-medium": (1.8e9, 1.8e9),
        "internvl2-1b": (0.49e9, 0.49e9),
    }
    for arch, (t0, a0) in expect.items():
        t, a = configs.get(arch).param_count()
        assert abs(t - t0) / t0 < 0.06, (arch, t, t0)
        assert abs(a - a0) / a0 < 0.11, (arch, a, a0)


def test_runnable_matrix():
    from repro.configs.base import SHAPES, runnable
    cells = [(a, s) for a in configs.ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnables = [(a, s) for a, s in cells if runnable(configs.get(a), SHAPES[s])[0]]
    skipped = [(a, s) for a, s in cells if not runnable(configs.get(a), SHAPES[s])[0]]
    # long_500k skipped exactly for the 6 pure full-attention archs
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "phi3.5-moe-42b-a6.6b", "granite-moe-1b-a400m", "qwen2.5-14b",
        "llama3.2-3b", "musicgen-medium", "internvl2-1b",
    }
    assert len(runnables) == 34
