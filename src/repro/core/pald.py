"""Public PaLD API — thin facades over the execution-plan engine.

    from repro.core import pald
    C = pald.cohesion(D)                      # auto method selection
    C = pald.cohesion(D, method="pairwise")   # blocked pairwise (Fig. 5)
    C = pald.cohesion(D, method="triplet")    # block-symmetric (Alg. 2 analogue)
    C = pald.cohesion(D, method="kernel")     # Pallas TPU kernels (dense grid)
    C = pald.cohesion(D, method="kernel",
                      schedule="tri")         # upper-tri kernel pipeline
    C = pald.cohesion(D, method="dense")      # un-blocked vectorized baseline
    C = pald.cohesion(D, method="knn", k=32)  # sparse O(n k^2) restriction
    C = pald.cohesion(Db)                     # batched: (B, n, n) -> (B, n, n)
    C = pald.from_features(X, metric="cosine")  # fused, from feature vectors

    p = pald.plan(D, method="auto")           # resolve once ...
    C = p.execute(D)                          # ... run (and re-run) anywhere
    p.explain()                               # what resolved, and why

Every knob — auto method via the tuning cache, ``block="auto"`` tiles, impl
defaults, tie semantics, batching — is resolved exactly once by
``pald.plan`` (``core/engine.py``); ``cohesion`` and ``from_features`` are
``plan(...).execute(x)`` with no method branching of their own.  The
executor registry maps each resolved ``(kind, method, schedule)`` cell to a
callable contributed by ``core/pairwise``, ``core/triplet`` and
``kernels/ops`` (DESIGN.md §11).

Inputs of any size are padded internally to a block multiple with +inf
distances; padded points land outside every local focus and contribute
nothing, so the result restricted to the original n x n is exact.

Dtype contract: every entry point casts its input to float32 exactly once
at the executor boundary (float64 inputs are downcast explicitly — PaLD
depends only on the order of distances, which f32 preserves away from ulp
collisions) and always returns float32.

Input contract: the plan layer rejects non-square or wrong-rank ``D`` and
any matrix whose diagonal is not exactly zero (cheap, always on);
``check=True`` additionally verifies finiteness, symmetry and
nonnegativity — worth it at the boundary of a serving path, skipped by
default on the hot path.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from .engine import PaldPlan, pad_distance_matrix  # noqa: F401
from .engine import plan as _engine_plan
from .weights import (  # noqa: F401
    DEFAULT_TIES,
    TIE_MODES,
    WeightFunctional,
    register_weight,
    registered_weights,
    validate_ties,
)

Method = Literal["auto", "dense", "pairwise", "triplet", "kernel"]
Ties = Literal["drop", "split", "ignore"]

__all__ = ["cohesion", "from_features", "plan", "local_depths",
           "pad_distance_matrix", "PaldPlan", "WeightFunctional",
           "register_weight", "registered_weights"]


def plan(x=None, **kwargs) -> PaldPlan:
    """Resolve a PaLD execution plan exactly once.

    ``pald.plan(D)`` plans the distance pipeline, ``pald.plan(X,
    kind="features", metric=...)`` the feature pipeline; shape-only planning
    (``pald.plan(n=4096)``) works too, for inspection before data exists.

    Args:
        x: optional input array the plan is keyed on — a (n, n) / (B, n, n)
            distance matrix or, with ``kind="features"``, a (n, d) /
            (B, n, d) feature matrix.  Omit it and pass ``n=`` (and ``d=``)
            for shape-only planning.
        **kwargs: every knob of ``cohesion`` / ``from_features`` (method,
            schedule, block, block_z, z_chunk, metric, normalize, impl,
            ties, weight, batch, check, k, on_error) plus
            ``kind``/``n``/``d``; full semantics in
            ``repro.core.engine.plan``.

    Returns:
        A frozen ``PaldPlan``.  ``plan.execute(x)`` runs it (reusable
        across calls, threads and same-shape inputs); ``plan.explain()``
        reports every resolved knob with its provenance.

    Raises:
        ValueError: on contradictory or unknown knobs — validation rejects
            them at this one boundary instead of silently dropping any
            (each message names the legal alternatives).

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1., 2.], [1., 0., 1.5], [2., 1.5, 0.]])
        >>> p = plan(D, method="triplet", block=2)
        >>> p.explain()["method"]
        'triplet'
        >>> p.execute(D).shape
        (3, 3)
    """
    return _engine_plan(x, **kwargs)


def cohesion(
    D: jnp.ndarray,
    *,
    method: Method = "auto",
    block: int | str | None = None,
    block_z: int | str | None = None,
    schedule: str = "dense",
    normalize: bool = True,
    z_chunk: int | None = None,
    impl: str | None = None,
    ties: Ties | None = None,
    weight: str | WeightFunctional | None = None,
    batch: int | None = None,
    check: bool = False,
    k: int | None = None,
    on_error: str = "raise",
) -> jnp.ndarray:
    """Compute the PaLD cohesion matrix C from a distance matrix D.

    Args:
        D: (n, n) distance matrix with an exactly-zero diagonal, or a
            batched (B, n, n) stack — every method and schedule accepts
            the batched form.  Any float dtype; cast to float32 once at
            the executor boundary.
        method: "dense" (un-blocked vectorized), "pairwise" (blocked
            Fig. 5), "triplet" (block-symmetric), "kernel" (Pallas
            pipeline), "knn" (sparse O(n*k^2) neighborhood restriction,
            needs ``k=``; exact at k = n-1), or "auto" (measured
            crossover from the tuning cache; never picks the knn
            approximation).
        block: tile size for the blocked paths; "auto" resolves via the
            tuning cache.  ``method="dense"`` has no tile and ignores it.
        block_z: z-axis tile (kernel pipeline only).
        schedule: "dense", or "tri" (kernel only) — the upper-triangular
            block schedule, half the block-pair visits of both passes.
        normalize: apply the 1/(n-1) factor (Eq. 3.3), making row sums
            equal local depths; on by default.
        z_chunk: third-point streaming chunk (dense method only).
        impl: kernel backend — 'pallas' (TPU), 'interpret' (bit-faithful
            CPU kernel execution), 'jnp' (vectorized fallback);
            kernel/fused/knn paths only.
        ties: what an exact distance tie means — the SAME answer on every
            method/schedule/impl (DESIGN.md §9, docs/guides.md):
            'drop' (default) a tied z supports neither point (strict
            comparisons, cheapest); 'split' ties split support 0.5/0.5
            incl. fractional focus-boundary membership (conserves total
            cohesion mass on any input); 'ignore' Algorithm 1's
            sequential if/else (higher index wins).  On tie-free
            distances all three agree.  Sugar for ``weight=`` restricted
            to the built-in modes; passing both with different names is
            an error.
        weight: the general knob behind ``ties``— a registered weight-
            functional name (``registered_weights()``) or a
            ``WeightFunctional`` instance (``core/weights.py``), e.g.
            ``weight="soft"`` / ``weight=soft_threshold(tau=0.05)`` for
            the sigmoid soft-threshold family or ``weight="kernelized"``
            for kernel-smoothed support shares.  Runs on every
            method/schedule/impl cell with no kernel forks; default is
            the 'drop' built-in.
        batch: for (B, n, n) input, how many items are vmapped per
            compiled call (None = all); bounds peak memory.
        check: add deep input validation (finite, symmetric, nonnegative)
            on top of the always-on shape/zero-diagonal checks.
        k: neighborhood size, ``method="knn"`` only (k >= 1, clamped to
            n-1).  Passing ``k=`` alone pins ``method="knn"``.
        on_error: "raise" (default) propagates the first executor failure
            unchanged; "fallback" degrades instead of crashing — OOM on a
            batched call halves ``batch`` down to 1, any other failure
            walks the cell's degradation chain (impl walk, then the
            blocked jnp paths, then the numpy reference oracle) with
            identical ties/normalize semantics.  Degradations are
            recorded in ``plan(...).explain()["degradations"]`` and warn
            once per cause (``resilience.DegradationWarning``).

    Returns:
        C as float32, shaped like D ((n, n) or (B, n, n)).  C[x, z] is
        the support z lends x across all of x's conflicts; row sums are
        the local depths (``pald.local_depths``).

    Raises:
        ValueError: non-square/ill-shaped D, a nonzero diagonal,
            ``check=True`` violations, or contradictory knobs (e.g.
            ``k=`` off the knn method, ``schedule="tri"`` off the kernel
            method) — each message names the legal alternatives.

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1., 4.], [1., 0., 2.], [4., 2., 0.]])
        >>> C = cohesion(D)
        >>> C.shape, bool(C[0, 1] > C[0, 2])   # 1 is 0's strong partner
        ((3, 3), True)
    """
    p = _engine_plan(
        D, kind="distance", method=method, schedule=schedule, block=block,
        block_z=block_z, z_chunk=z_chunk, normalize=normalize, impl=impl,
        ties=ties, weight=weight, batch=batch, check=check, k=k,
        on_error=on_error,
    )
    return p.execute(D)


def from_features(
    X: jnp.ndarray,
    *,
    metric: str = "euclidean",
    method: str = "auto",
    batch: int | None = None,
    block: int | str = "auto",
    block_z: int | str | None = None,
    schedule: str = "dense",
    normalize: bool = True,
    impl: str | None = None,
    ties: str | None = None,
    weight: str | WeightFunctional | None = None,
    check: bool = False,
    k: int | None = None,
    on_error: str = "raise",
    select: str | None = None,
    select_block: int | str | None = None,
    select_tile: int | str | None = None,
    mesh=None,
    strategy: str | None = None,
) -> jnp.ndarray:
    """PaLD cohesion straight from feature vectors.

    Args:
        X: (n, d) feature matrix or batched (B, n, d) stack.  Any float
            dtype — cast to float32 once at the executor boundary (PaLD
            only consumes the ORDER of distances, which f32 preserves for
            any non-pathological data).
        metric: one of ``features.METRICS`` (sqeuclidean, euclidean,
            cosine, manhattan).
        method: "fused" (the "auto" default) computes distance tiles
            in-register from feature tiles — the full D matrix never
            exists in HBM; "knn" selects k-nearest neighborhoods with
            row-chunked distance slabs (D never materialized either) and
            runs the sparse O(n*k^2) restriction; "dense" / "pairwise" /
            "triplet" / "kernel" materialize D once (``cdist_reference``)
            and run the corresponding distance executor.
        batch: for 3-D X, how many batch elements to vmap per compiled
            call (None = all at once); bounds peak memory at
            ``batch * n^2`` floats.
        block: kernel tile; "auto" consults the tuning cache (the
            ``pald_fused`` pass is keyed by (n, d), the knn pass by
            (n, k)).
        block_z: z tile, fused/kernel methods only.
        schedule: "dense", or "tri" with ``method="kernel"``.
        normalize: apply the 1/(n-1) factor; on by default.
        impl: kernel backend ('pallas', 'interpret', 'jnp');
            kernel/fused/knn methods only — the pure-jnp blocked paths
            reject an explicit impl rather than silently dropping it.
        ties: 'drop' (default) / 'split' / 'ignore' — what an exact
            distance tie means, identically on every method (see
            ``pald.cohesion``).  Quantized or duplicated feature rows
            produce exact ties in every metric, so this matters for real
            embedding data; 'split' is the theoretically-faithful choice
            there.  Sugar for ``weight=``.
        weight: registered weight-functional name or ``WeightFunctional``
            instance — the general contribution algebra behind ``ties``;
            see ``pald.cohesion`` and ``core/weights.py``.
        check: deep input validation (finiteness) on top of shape checks.
        k: neighborhood size for ``method="knn"``.  The knn executor is
            the fused select->cohere pipeline: streaming top-k selection
            feeds the sparse cohesion tile body directly, no
            ``NeighborGraph`` or distance matrix in between.
        on_error: "raise" (default) or "fallback" — identical failure
            semantics to ``pald.cohesion``; the feature cells degrade
            through the materialize-D compositions before the reference
            oracle, and the knn cell through the selection impls down to
            the row-chunked ``lax.top_k`` rung.
        select: knn selection-stage impl override ('pallas'/'interpret'/
            'jnp'/'chunked'); None follows ``impl``.
        select_block: rows per selection slab ("auto"/None = the
            ``pald_topk:k<k>:d<d>`` tuning-cache pass).
        select_tile: tile-min prefilter width for the jnp selection
            strategy (a value >= n disables the prefilter; "auto"/None =
            tuned).
        mesh: a ``jax.sharding.Mesh`` to shard the fused select->cohere
            knn pipeline across (``method="knn"`` only) — rows of X are
            sharded over all mesh axes, feature blocks rotate by
            ``strategy``, and the result stays bitwise-equal to the
            single-device fused path (``core/distributed_knn.py``).
        strategy: mesh comm pattern — 'allgather', 'ring', or '2d'
            ('auto'/None picks '2d' on a >= 2-axis mesh, 'ring'
            otherwise); requires ``mesh=``.

    Returns:
        C as float32: (n, n) for 2-D X, (B, n, n) for batched input.

    Raises:
        ValueError: unknown metric/method, contradictory knobs, or
            ``check=True`` violations.

    Example:
        >>> import jax.numpy as jnp
        >>> X = jnp.asarray([[0.0, 0.0], [0.1, 0.0], [5.0, 5.0]])
        >>> C = from_features(X, metric="euclidean")
        >>> C.shape
        (3, 3)
    """
    p = _engine_plan(
        X, kind="features", metric=metric, method=method, schedule=schedule,
        block=block, block_z=block_z, normalize=normalize, impl=impl,
        ties=ties, weight=weight, batch=batch, check=check, k=k,
        on_error=on_error, select=select, select_block=select_block,
        select_tile=select_tile, mesh=mesh, strategy=strategy,
    )
    return p.execute(X)


def local_depths(C: jnp.ndarray) -> jnp.ndarray:
    """Local depths from a cohesion matrix (PaLD *partitions* local depth).

    Args:
        C: (..., n, n) cohesion matrix from ``cohesion``/``from_features``.

    Returns:
        (..., n) row sums l_x = sum_z c_xz.  With the default
        ``normalize=True`` upstream, sum(l) == n/2 exactly.

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1.], [1., 0.]])
        >>> float(local_depths(cohesion(D)).sum())
        1.0
    """
    return jnp.sum(C, axis=-1)
