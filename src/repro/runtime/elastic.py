"""Elastic-restart policy: resume a job on whatever healthy devices remain.

At pod scale, node failure is routine; the recovery path must not require
the original device count.  The policy here:

1. ``choose_mesh(n_devices)`` — pick the largest (data, model) production
   mesh that fits the surviving device count, holding the model axis at the
   largest power-of-two ≤ the target TP width that the arch configs assume
   (16), shrinking the data axis first (DP/FSDP degree is elastic; TP is
   not, because parameter head/ff splits assume it).
2. ``resume(...)`` — restore the latest complete checkpoint with the new
   mesh's shardings (the checkpoint format is mesh-free: host numpy +
   manifest), rebuild the step functions, and continue.  The data pipeline
   is a pure function of (seed, step), so the resumed run replays the exact
   stream from the restored step.

Straggler/failure model: all collectives are bulk-synchronous, so a slow or
dead chip stalls its pod; detection (timeout on a heartbeat collective) is
the runtime layer above this module, and its response is exactly this
resume path on the reduced mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpointer
from repro.sharding import partition

TARGET_MODEL_AXIS = 16


def choose_mesh(n_devices: Optional[int] = None, *,
                target_model: int = TARGET_MODEL_AXIS) -> Mesh:
    """Largest (data, model) mesh fitting the surviving devices."""
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    assert n >= 1
    model = 1
    while model * 2 <= min(target_model, n):
        model *= 2
    data = n // model
    arr = np.asarray(devs[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def state_shardings(cfg, mesh: Mesh, abstract_state: Any, specs: Any):
    psh = partition.param_shardings(
        specs["params"], cfg.sharding_profile, mesh, abstract_state["params"]
    )
    return {
        "params": psh,
        "opt": {"m": psh, "v": psh},
        "step": NamedSharding(mesh, P()),
    }


def resume(cfg, ckpt_dir: str, abstract_state: Any, specs: Any,
           mesh: Optional[Mesh] = None):
    """Restore the latest checkpoint onto ``mesh`` (or an auto-chosen one).

    Returns (state, restored_step, mesh); state is None if no checkpoint.
    """
    mesh = mesh or choose_mesh()
    shardings = state_shardings(cfg, mesh, abstract_state, specs)
    state, step = checkpointer.restore_latest(ckpt_dir, abstract_state, shardings)
    return state, step, mesh
