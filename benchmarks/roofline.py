"""Render the roofline table from the dry-run JSON dumps.

    PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/dryrun_out]

Per (arch x shape x mesh) cell: the three roofline terms in seconds, the
dominant term, MODEL_FLOPS/HLO_FLOPS, and bytes/device.  Markdown to stdout
(pasted into EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_cell(c: dict) -> str:
    if c.get("status") == "skipped":
        return (f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"skipped | — | — |")
    if c.get("status") != "ok":
        return (f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"ERROR | — | — |")
    r = c["roofline"]
    mem = c.get("memory_analysis", {})
    bpd = (mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)) / 2**30
    uf = c.get("useful_flop_ratio")
    uf_s = f"{uf:.3f}" if uf else "n/a"
    return (
        f"| {c['arch']} | {c['shape']} | {c['mesh']} "
        f"| {r['compute_s']*1e3:9.2f} | {r['memory_s']*1e3:9.2f} "
        f"| {r['collective_s']*1e3:9.2f} | {r['bottleneck']} "
        f"| {uf_s} | {bpd:7.2f} |"
    )


def render(cells: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| bottleneck | useful-flop ratio | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    cells = sorted(cells, key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"]))
    for c in cells:
        out.append(fmt_cell(c))
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    err = [c for c in cells if c.get("status") not in ("ok", "skipped")]
    out.append("")
    out.append(f"cells: {len(ok)} ok, {len(skipped)} skipped, {len(err)} errors")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/dryrun_out")
    args = ap.parse_args()
    print(render(load(args.dir)))


if __name__ == "__main__":
    main()
