"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step), so a restarted job replays the
exact same stream from its restored step — the restart-exactness property
the checkpointing layer relies on (no data-loader state to snapshot).

On a real cluster each host materializes only its addressable shard via
``jax.make_array_from_callback``; in this single-process container that
degenerates to a sharded device_put, same code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class SyntheticTokens:
    """Zipf-ish synthetic LM tokens with next-token labels."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        mesh: Optional[Mesh] = None,
        batch_spec: Optional[P] = None,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.mesh = mesh
        self.spec = batch_spec if batch_spec is not None else P(None)

    def _host_batch(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step`` (deterministic)."""
        rng = np.random.default_rng(
            np.uint64(self.seed) * np.uint64(1_000_003) + np.uint64(step)
        )
        # skip to row block without materializing all rows: per-row generators
        out = np.empty((hi - lo, self.seq + 1), np.int32)
        for i, row in enumerate(range(lo, hi)):
            r = np.random.default_rng(
                (np.uint64(self.seed) << np.uint64(20))
                ^ np.uint64(step * 131_071 + row)
            )
            u = r.random(self.seq + 1)
            out[i] = np.minimum(
                (u ** 3.0 * self.vocab).astype(np.int32), self.vocab - 1
            )
        _ = rng
        return out

    def batch_at(self, step: int) -> dict:
        if self.mesh is None:
            arr = self._host_batch(step, 0, self.batch)
            return {"tokens": jnp.asarray(arr[:, :-1]), "labels": jnp.asarray(arr[:, 1:])}
        sharding = NamedSharding(self.mesh, P(*self.spec, None))

        def cb(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else self.batch
            return self._host_batch(step, lo, hi)

        full = jax.make_array_from_callback(
            (self.batch, self.seq + 1), sharding, cb
        )
        return {"tokens": full[:, :-1], "labels": full[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
