"""Dtype contract of the public API boundary.

float64 / bfloat16 inputs used to be downcast silently somewhere mid-
pipeline (wherever the first ``.astype(jnp.float32)`` happened to live);
the cast is now explicit at the API boundary — ``pad_distance_matrix``,
``pald.cohesion`` and ``features.from_features`` — and the output dtype is
always float32, asserted here for every entry point.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import features, pald

from conftest import euclidean_distance_matrix


@pytest.fixture
def D64(rng):
    X = rng.normal(size=(21, 4))
    return euclidean_distance_matrix(X)  # float64 numpy


@pytest.mark.parametrize("dtype", [np.float64, np.float32, jnp.bfloat16])
@pytest.mark.parametrize("method", ["dense", "pairwise", "triplet", "kernel"])
def test_cohesion_output_dtype(D64, dtype, method):
    D = jnp.asarray(D64).astype(dtype)
    C = pald.cohesion(D, method=method, block=16)
    assert C.dtype == jnp.float32
    # the downcast must happen before blocking, not mid-pipeline: a bf16
    # input gives the same result as pre-casting it to f32 by hand
    C2 = pald.cohesion(jnp.asarray(D, jnp.float32), method=method, block=16)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C2),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dtype", [np.float64, np.float32, jnp.bfloat16])
def test_from_features_output_dtype(rng, dtype):
    X = jnp.asarray(rng.normal(size=(19, 3))).astype(dtype)
    C = pald.from_features(X, metric="euclidean", block=16, block_z=16)
    assert C.dtype == jnp.float32
    D = features.cdist_reference(X, metric="sqeuclidean")
    assert D.dtype == jnp.float32


def test_pad_distance_matrix_casts_explicitly(D64):
    # f64 numpy in -> f32 padded out, diag zero, +inf fill
    P, n0 = pald.pad_distance_matrix(D64, 16)
    assert P.dtype == jnp.float32
    assert n0 == 21 and P.shape == (32, 32)
    assert np.isinf(np.asarray(P)[0, -1])
    assert (np.diag(np.asarray(P)) == 0).all()
    # exact-multiple inputs are cast too (no pad branch shortcut)
    P2, _ = pald.pad_distance_matrix(D64[:16, :16], 16)
    assert P2.dtype == jnp.float32


def test_normalized_and_unnormalized_consistent(D64):
    n = D64.shape[0]
    Cn = np.asarray(pald.cohesion(jnp.asarray(D64), method="dense"))
    Cu = np.asarray(pald.cohesion(jnp.asarray(D64), method="dense",
                                  normalize=False))
    np.testing.assert_allclose(Cu / (n - 1), Cn, rtol=1e-6, atol=1e-7)
