"""Fused features→PaLD Pallas kernels: distance tiles computed in-register.

The dense kernels (``pald_focus`` / ``pald_cohesion``) consume a
materialized distance matrix — O(n^2) HBM traffic before pass 1 even
starts.  These variants take the (n, d) feature matrix instead: each grid
step loads the (block, d) feature tiles it needs, computes the
(block, block) / (block, block_z) distance tiles in VMEM via
``features.dist_tile`` (matmul-backed for sqeuclidean / euclidean / cosine,
d-streamed for manhattan), and then runs the *same* focus / cohesion tile
bodies as the dense kernels.  ``D`` never exists in HBM.

Grid shapes and the accumulator-residency discipline are identical to the
dense kernels (DESIGN.md §4.1); the only new cost is recomputing distance
tiles on revisit, an O(d/block) relative overhead that is far cheaper than
streaming them from HBM for any d << n.

Padding contract: feature rows are zero-padded (``features.pad_features``);
the +inf-off-diagonal / zero-diagonal semantics of ``pad_distance_matrix``
are re-imposed per tile by ``features.masked_dist_tile`` using the static
``n_valid`` and each tile's global row/col offsets — so padded points land
outside every real focus exactly as in the materialized paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.features import masked_dist_tile
from repro.core.weights import (DEFAULT_TIES, focus_weight, resolve_weight,
                                support_weight)

__all__ = ["focus_fused_pallas", "cohesion_fused_pallas"]


def _focus_fused_kernel(xi_ref, xj_ref, xk_ref, u_ref, *, metric, n_valid,
                        block, block_y, block_z, ties):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    xoff = pl.program_id(0) * block
    yoff = pl.program_id(1) * block_y
    zoff = k * block_z
    dxz = masked_dist_tile(xi_ref[...], xk_ref[...], metric, xoff, zoff,
                           n_valid, loop_d=True)   # (bx, bz)
    dyz = masked_dist_tile(xj_ref[...], xk_ref[...], metric, yoff, zoff,
                           n_valid, loop_d=True)   # (by, bz)
    dxy = masked_dist_tile(xi_ref[...], xj_ref[...], metric, xoff, yoff,
                           n_valid, loop_d=True)   # (bx, by)
    by = dxy.shape[1]

    # identical tile body to pald_focus._focus_kernel
    def body(y, acc):
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)      # (bx, 1)
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)      # (1, bz)
        m = focus_weight(dxz, row, thr, ties)
        col = jnp.sum(m, axis=1, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(acc, col, y, axis=1)

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(u_ref))
    u_ref[...] += add


@functools.partial(jax.jit, static_argnames=(
    "metric", "n_valid", "block", "block_y", "block_z", "interpret", "ties"))
def focus_fused_pallas(
    X: jnp.ndarray,            # (m, d) zero-padded features
    *,
    metric: str = "euclidean",
    n_valid: int,
    block: int = 128,
    block_y: int | None = None,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """U (m, m) local-focus sizes computed straight from feature tiles."""
    ties = resolve_weight(ties)
    m, d = X.shape
    block_y = block_y or block
    assert m % block == 0 and m % block_y == 0 and m % block_z == 0
    grid = (m // block, m // block_y, m // block_z)
    kernel = functools.partial(
        _focus_fused_kernel, metric=metric, n_valid=n_valid,
        block=block, block_y=block_y, block_z=block_z, ties=ties,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j, k: (i, 0)),     # X rows (x)
            pl.BlockSpec((block_y, d), lambda i, j, k: (j, 0)),   # X rows (y)
            pl.BlockSpec((block_z, d), lambda i, j, k: (k, 0)),   # X rows (z)
        ],
        out_specs=pl.BlockSpec((block, block_y), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(X.astype(jnp.float32), X.astype(jnp.float32), X.astype(jnp.float32))


def _cohesion_fused_kernel(xi_ref, xj_ref, xk_ref, w_ref, c_ref, *, metric,
                           n_valid, block, block_y, block_z, ties):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    xoff = pl.program_id(0) * block
    zoff = pl.program_id(1) * block_z
    yoff = k * block_y
    dxz = masked_dist_tile(xi_ref[...], xj_ref[...], metric, xoff, zoff,
                           n_valid, loop_d=True)   # (bx, bz)
    dyz = masked_dist_tile(xk_ref[...], xj_ref[...], metric, yoff, zoff,
                           n_valid, loop_d=True)   # (by, bz)
    dxy = masked_dist_tile(xi_ref[...], xk_ref[...], metric, xoff, yoff,
                           n_valid, loop_d=True)   # (bx, by)
    w = w_ref[...]                                 # (bx, by)
    by = dxy.shape[1]
    bx = dxz.shape[0]
    xg = xoff + jax.lax.broadcasted_iota(jnp.int32, (bx, 1), 0)

    # identical tile body to pald_cohesion._cohesion_kernel; the grid owns
    # both offsets, so the index tiebreak is an in-kernel iota
    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)   # (1, bz)
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)   # (bx, 1)
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)      # (bx, 1)
        xw = (xg > yoff + y) if ties.needs_index_tiebreak else None
        g = support_weight(dxz, row, thr, ties, xw)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


@functools.partial(jax.jit, static_argnames=(
    "metric", "n_valid", "block", "block_y", "block_z", "interpret", "ties"))
def cohesion_fused_pallas(
    X: jnp.ndarray,            # (m, d) zero-padded features
    W: jnp.ndarray,            # (m, m) reciprocal weights
    *,
    metric: str = "euclidean",
    n_valid: int,
    block: int = 128,
    block_y: int | None = None,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """C (m, m) cohesion from feature tiles + precomputed weights."""
    ties = resolve_weight(ties)
    m, d = X.shape
    block_y = block_y or block
    assert W.shape == (m, m)
    assert m % block == 0 and m % block_y == 0 and m % block_z == 0
    grid = (m // block, m // block_z, m // block_y)
    kernel = functools.partial(
        _cohesion_fused_kernel, metric=metric, n_valid=n_valid,
        block=block, block_y=block_y, block_z=block_z, ties=ties,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j, k: (i, 0)),     # X rows (x)
            pl.BlockSpec((block_z, d), lambda i, j, k: (j, 0)),   # X rows (z)
            pl.BlockSpec((block_y, d), lambda i, j, k: (k, 0)),   # X rows (y)
            pl.BlockSpec((block, block_y), lambda i, j, k: (i, k)),  # W[X, Y]
        ],
        out_specs=pl.BlockSpec((block, block_z), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(X.astype(jnp.float32), X.astype(jnp.float32), X.astype(jnp.float32),
      W.astype(jnp.float32))
