"""Framework self-test: exercises every subsystem end-to-end on this host.

    PYTHONPATH=src python -m repro.launch.selftest

Runs in a few minutes on CPU: PaLD correctness (all 4 methods + distributed),
one reduced arch through train/prefill/decode, a checkpoint save/restore,
and a tiny production-mesh lowering (no compile).  Exit code 0 = healthy.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def main() -> int:
    t0 = time.time()
    import jax
    import jax.numpy as jnp

    failures = []

    def check(name, fn):
        t = time.time()
        try:
            fn()
            print(f"  ok   {name} ({time.time()-t:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"  FAIL {name}: {e}")

    print(f"[selftest] devices: {len(jax.devices())} {jax.default_backend()}")

    # --- PaLD core ----------------------------------------------------------
    def pald_core():
        from repro.core import pald, reference
        rng = np.random.default_rng(0)
        X = rng.normal(size=(40, 4))
        D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))
        Cref = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
        for m in ("dense", "pairwise", "triplet", "kernel"):
            C = np.asarray(pald.cohesion(jnp.asarray(D), method=m, block=16))
            assert np.allclose(C, Cref, atol=1e-5), m

    check("pald core (4 methods vs reference)", pald_core)

    # --- distributed --------------------------------------------------------
    def pald_dist():
        from repro.core import distributed, reference
        from repro.launch import mesh as meshlib
        if len(jax.devices()) < 2:
            return
        rng = np.random.default_rng(1)
        X = rng.normal(size=(48, 4))
        D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))
        Cref = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
        p = min(4, len(jax.devices()))
        mesh = meshlib.make_test_mesh((p,), ("data",))
        C = np.asarray(distributed.pald_distributed(D, mesh, strategy="ring", impl="jnp"))
        assert np.allclose(C, Cref, atol=1e-5)

    check("pald distributed (ring)", pald_dist)

    # --- one arch through train + serve -------------------------------------
    def lm_cycle():
        from repro import configs
        from repro.configs.base import reduced
        from repro.models.model import Model
        from repro.train.train_step import init_state, make_train_step
        cfg = reduced(configs.get("gemma2-2b"))
        key = jax.random.PRNGKey(0)
        model = Model(cfg)
        state, _ = init_state(cfg, key)
        step = jax.jit(make_train_step(cfg))
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        state, m = step(state, {"tokens": toks, "labels": toks})
        assert np.isfinite(float(m["loss"]))
        caches = model.init_caches(2, 20)
        lg, caches = model.prefill(state["params"], {"tokens": toks}, caches)
        lg, caches = model.decode_step(
            state["params"],
            jnp.argmax(lg[..., :cfg.vocab_size], -1)[:, None].astype(jnp.int32),
            caches, jnp.asarray(16, jnp.int32))
        assert not np.isnan(np.asarray(lg[..., :cfg.vocab_size])).any()

    check("lm train+prefill+decode (gemma2 reduced)", lm_cycle)

    # --- checkpoint ----------------------------------------------------------
    def ckpt():
        import tempfile
        from repro.checkpoint import checkpointer
        t = {"a": jnp.arange(4.0)}
        with tempfile.TemporaryDirectory() as d:
            checkpointer.save(d, 1, t)
            r, at = checkpointer.restore_latest(d, jax.eval_shape(lambda: t))
            assert at == 1 and np.allclose(np.asarray(r["a"]), np.asarray(t["a"]))

    check("checkpoint save/restore", ckpt)

    # --- abstract lowering of one production cell ----------------------------
    def lower_abstract():
        from repro import configs
        from repro.configs.base import ShapeConfig
        from repro.launch import mesh as meshlib, specs
        n = len(jax.devices())
        if n < 4:
            return
        mesh = meshlib.make_test_mesh((n // 2, 2), ("data", "model"))
        cfg = configs.get("internvl2-1b")
        fn, args = specs.cell_lowerable(
            cfg, ShapeConfig("t", 256, 8, "train"), mesh, q_chunk=128)
        with mesh:
            jax.jit(fn).lower(*args)   # no compile: just shape/sharding check

    check("abstract lowering (full internvl2-1b)", lower_abstract)

    print(f"[selftest] {'FAILED: ' + ', '.join(failures) if failures else 'all healthy'} "
          f"({time.time()-t0:.1f}s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
