"""Cohesion-matrix analysis: universal threshold, strong ties, communities.

Follows Berenhaut, Moore & Melvin (PNAS 2022), the paper's reference [2]:

* the *universal threshold* for distinguishing strong from weak ties is half
  the mean self-cohesion:  tau = mean(diag(C)) / 2;
* the strong-tie matrix keeps symmetrized cohesion min(c_xy, c_yx) where it
  exceeds tau;
* communities are the connected components of the strong-tie graph.
"""
from __future__ import annotations

import numpy as np

__all__ = ["universal_threshold", "strong_ties", "communities",
           "connected_components", "top_ties"]


def universal_threshold(C: np.ndarray) -> float:
    """The universal strong/weak tie threshold: half the mean self-cohesion.

    Args:
        C: (n, n) NORMALIZED cohesion matrix (``pald.cohesion`` /
            ``from_features`` with the default ``normalize=True``, i.e.
            entries carry the 1/(n-1) factor).  On an un-normalized C
            every entry — diagonal and off-diagonal alike — scales by
            (n-1), so the *partition* into strong and weak ties is
            unchanged, but the returned tau is on the un-normalized scale
            and must not be compared against normalized cohesion values.

    Returns:
        tau = mean(diag(C)) / 2, the parameter-free threshold of
        Berenhaut, Moore & Melvin (PNAS 2022).

    Example:
        >>> import numpy as np
        >>> float(universal_threshold(np.eye(4) * 0.5))
        0.25
    """
    return float(np.mean(np.diag(C))) / 2.0


def strong_ties(C: np.ndarray, threshold: float | None = None) -> np.ndarray:
    """Symmetrized cohesion, zeroed below the universal threshold.

    Args:
        C: (n, n) normalized cohesion matrix.
        threshold: tau override; default ``universal_threshold(C)``.

    Returns:
        (n, n) matrix S = min(C, C.T) with a zero diagonal and entries
        below tau zeroed — the adjacency of the strong-tie graph.

    Example:
        >>> import numpy as np
        >>> C = np.asarray([[.5, .4], [.45, .5]])
        >>> strong_ties(C).tolist()
        [[0.0, 0.4], [0.4, 0.0]]
    """
    C = np.asarray(C)
    tau = universal_threshold(C) if threshold is None else threshold
    S = np.minimum(C, C.T)
    np.fill_diagonal(S, 0.0)
    S[S < tau] = 0.0
    return S


def communities(C: np.ndarray, threshold: float | None = None) -> list[list[int]]:
    """Community detection: connected components of the strong-tie graph.

    Args:
        C: (n, n) normalized cohesion matrix.
        threshold: tau override; default ``universal_threshold(C)``.

    Returns:
        List of components in deterministic order: sorted by size
        (largest first), equal sizes broken by smallest member index;
        members within a component in increasing index order.  Sorting by
        size alone would leave equal-size communities in union-find-root
        order — an artifact of edge iteration, not of the data.

    Example:
        >>> import numpy as np
        >>> C = np.asarray([[.5, .4, 0], [.4, .5, 0], [0, 0, .5]])
        >>> communities(C)
        [[0, 1], [2]]
    """
    S = strong_ties(C, threshold)
    return connected_components(S.shape[0], zip(*np.nonzero(S)))


def connected_components(n: int, edges) -> list[list[int]]:
    """Union-find components over ``edges`` with the deterministic output
    contract shared by the dense (``communities``) and sparse
    (``repro.core.knn.communities``) strong-tie analyses: components
    sorted by (-size, smallest member), members ascending.

    Args:
        n: number of nodes (0..n-1).
        edges: iterable of (x, y) pairs (any int-castable).

    Returns:
        The components as lists of node indices.

    Example:
        >>> connected_components(4, [(0, 2), (2, 3)])
        [[0, 2, 3], [1]]
    """
    parent = list(range(n))

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for x, y in edges:
        ra, rb = find(int(x)), find(int(y))
        if ra != rb:
            parent[ra] = rb
    groups: dict[int, list[int]] = {}
    for i in range(n):
        groups.setdefault(find(i), []).append(i)
    return sorted(groups.values(), key=lambda g: (-len(g), g[0]))


def top_ties(C: np.ndarray, x: int, k: int = 10) -> list[tuple[int, float]]:
    """Strongest symmetric ties of point x (paper §7 word-cloud analogue).

    Args:
        C: (n, n) cohesion matrix.
        x: the point whose ties to rank.
        k: how many partners to return; clamped to the n-1 real partners
            (a point has no tie to itself, so asking for more must not
            pad the list with the -inf self-sentinel).

    Returns:
        Up to k ``(partner_index, min(c_xy, c_yx))`` pairs, strongest
        first.

    Example:
        >>> import numpy as np
        >>> C = np.asarray([[.5, .4, .1], [.4, .5, .1], [.1, .1, .5]])
        >>> top_ties(C, 0, k=5)
        [(1, 0.4), (2, 0.1)]
    """
    C = np.asarray(C)
    n = C.shape[0]
    k = min(k, n - 1)
    if k <= 0:
        return []
    S = np.minimum(C, C.T)
    row = S[x].copy()
    row[x] = -np.inf
    idx = np.argsort(row)[::-1][:k]
    return [(int(i), float(row[i])) for i in idx]
