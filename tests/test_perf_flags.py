"""Every §Perf config flag must preserve model numerics (they only change
sharding/layout/precision-of-accumulation, never the math)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import reduced
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(configs.get("phi3.5-moe-42b-a6.6b"))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    ref, _ = model.apply(params, {"tokens": toks})
    return cfg, params, toks, np.asarray(ref, np.float32)


@pytest.mark.parametrize("flag,value,tol", [
    ("moe_shard_constraints", True, 1e-6),   # pure sharding hints
    ("batch_shard_constraint", False, 1e-6), # pure sharding hints
    ("attn_seq_proj", True, 1e-6),           # sharding hints (no-op w/o mesh)
    ("attn_out_f32", False, 5e-2),           # bf16 PV accumulation
    ("norm_f32", False, 5e-2),               # bf16 normalize
])
def test_flag_preserves_numerics(setup, flag, value, tol):
    cfg, params, toks, ref = setup
    cfg2 = dataclasses.replace(cfg, **{flag: value})
    out, _ = Model(cfg2).apply(params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[..., : cfg.vocab_size],
        ref[..., : cfg.vocab_size], rtol=tol, atol=tol,
    )


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_flags_match_under_mesh():
    """Sharding-hint flags are bit-compatible under a real mesh too."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import mesh as meshlib

    cfg = reduced(configs.get("granite-moe-1b-a400m"))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    mesh = meshlib.make_test_mesh((2, 2), ("data", "model"))

    outs = {}
    for name, ov in [("plain", {}), ("hints", dict(
            moe_shard_constraints=True, batch_shard_constraint=True))]:
        cfg2 = dataclasses.replace(cfg, **ov)
        with mesh:
            tokens = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
            out, _ = jax.jit(lambda p, t: Model(cfg2).apply(p, {"tokens": t}))(
                params, tokens)
        outs[name] = np.asarray(out, np.float32)
    np.testing.assert_allclose(outs["plain"], outs["hints"], rtol=2e-5, atol=2e-5)
