"""Guarded execution: degradation chains, OOM-aware retries, fault points.

The engine's speed comes from picking tuned variants per problem size —
which means a result can now depend on a persistent JSON cache, on
backend-specific Pallas kernels, and on memory-hungry batched vmaps, any of
which can fail at runtime.  A long sharded run or a serving process must
degrade, not crash: this module is the robustness substrate (DESIGN.md §13)
that every scale-out consumer builds on.

Three public surfaces:

``on_error="raise" | "fallback"`` (a ``pald.plan`` knob)
    ``"raise"`` (default) keeps the exact pre-existing behavior: the first
    executor failure propagates unchanged.  ``"fallback"`` walks a
    registered DEGRADATION CHAIN for the plan's ``(kind, method, schedule)``
    cell — impl degradation (pallas → interpret → jnp) first, then
    method-level degradation onto the blocked/un-blocked jnp paths, then the
    entry-wise numpy reference oracle — re-executing with identical
    ``ties``/``normalize`` semantics at every step.  The knn cells degrade
    across impls and end on the ``select:chunked`` rung — row-chunked
    ``lax.top_k`` selection feeding jnp cohesion — never onto a dense
    method (no other path shares their sparse semantics).

OOM-aware batched execution
    In fallback mode, a ``RESOURCE_EXHAUSTED`` failure of the chunked-vmap
    batch layer retries with a halved ``batch`` (down to 1) before touching
    the chain at all — chunked execution is a pure re-chunking of the same
    computation (bitwise-equal, asserted in test_conformance.py), so this
    degradation never changes values.

Structured degradation events
    Every retry/fallback appends an event dict (cell, cause, fallback used,
    retry count) to the plan, surfaced via ``plan.explain()["degradations"]``
    and a once-per-cause ``warnings.warn(DegradationWarning)`` so a serving
    log shows each failure class exactly once instead of per-request spam.

The FAULT-POINT substrate at the bottom is the injection surface the test
harness (``repro.testing.faults``) arms: named call sites threaded through
the engine dispatch, the kernel entry points and the feature front-end that
are zero-cost no-ops until a test registers a ``FaultRule``.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "ON_ERROR_MODES",
    "DegradationWarning",
    "FallbackExhausted",
    "FallbackUnavailable",
    "FaultRule",
    "Step",
    "arm",
    "disarm",
    "fault_point",
    "is_oom",
    "simulated_oom",
    "chain_for",
    "register_chain",
    "execute_plan",
    "guarded_general",
    "warn_once",
    "reset_warnings",
]

ON_ERROR_MODES = ("raise", "fallback")

# impl preference order of the degradation walk (the issue/DESIGN contract:
# pallas -> interpret -> jnp); entries that cannot run on this backend or
# that already failed are skipped at walk time, not at registration time.
IMPL_ORDER = ("pallas", "interpret", "jnp")


class DegradationWarning(UserWarning):
    """A guarded execution degraded (fallback taken / batch halved)."""


class FallbackExhausted(RuntimeError):
    """Every step of a degradation chain failed.

    Raised only with ``on_error="fallback"``; chained from the ORIGINAL
    executor failure so the root cause stays on the traceback.
    """


class FallbackUnavailable(RuntimeError):
    """A chain step cannot run in this context (e.g. the numpy reference
    oracle under jit/vmap tracing); treated as a failed step, walk
    continues."""


# ---------------------------------------------------------------------------
# OOM detection
# ---------------------------------------------------------------------------
_OOM_MARKERS = ("RESOURCE_EXHAUSTED", "out of memory", "Out of memory",
                "OutOfMemory")


def is_oom(exc: BaseException) -> bool:
    """Does this exception look like a memory-exhaustion failure?

    Matched on the message, not the type: XLA surfaces OOM as
    ``XlaRuntimeError: RESOURCE_EXHAUSTED ...`` (a type that cannot be
    constructed portably), host allocators as ``MemoryError`` or
    "out of memory" strings, and the fault harness as ``simulated_oom()``.
    """
    if isinstance(exc, MemoryError):
        return True
    text = f"{type(exc).__name__}: {exc}"
    return any(marker in text for marker in _OOM_MARKERS)


def simulated_oom(detail: str = "simulated") -> RuntimeError:
    """An exception that ``is_oom`` recognizes, for fault injection."""
    return RuntimeError(f"RESOURCE_EXHAUSTED: out of memory ({detail})")


# ---------------------------------------------------------------------------
# once-per-cause warnings
# ---------------------------------------------------------------------------
_WARNED: set = set()
_WARN_LOCK = threading.Lock()


def warn_once(key, message: str) -> None:
    """``warnings.warn(DegradationWarning)`` at most once per ``key``.

    A degraded serving path re-executes the same fallback per request;
    the log should record the failure class once, not once per call.
    """
    with _WARN_LOCK:
        if key in _WARNED:
            return
        _WARNED.add(key)
    warnings.warn(message, DegradationWarning, stacklevel=3)


def reset_warnings() -> None:
    """Forget which causes already warned (test isolation)."""
    with _WARN_LOCK:
        _WARNED.clear()


def _event(*, cell, cause: str, error: BaseException | None,
           fallback: str | None, retries: int, **extra) -> dict:
    evt = {
        "cell": tuple(cell),
        "cause": cause,
        "error": None if error is None else f"{type(error).__name__}: {error}",
        "fallback": fallback,
        "retries": retries,
    }
    evt.update(extra)
    return evt


# ---------------------------------------------------------------------------
# degradation chains
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Step:
    """One rung of a degradation chain.

    ``run(x, plan, batch)`` must re-execute the plan's computation with
    IDENTICAL ties/normalize semantics (degradation may change speed and
    floating-point association, never meaning).  ``batch`` carries the
    possibly-already-halved vmap chunk bound into the step.
    """

    label: str
    run: Callable[[Any, Any, Any], Any]


_CHAINS: dict[tuple, list] = {}  # (kind, method, schedule) -> [Step, ...]


def register_chain(kind: str, method: str, schedule: str,
                   steps: list) -> None:
    """Override the degradation chain for one (kind, method, schedule) cell.

    The default chains (built lazily by ``chain_for``) cover every built-in
    cell; alternative backends that ``register_executor`` new cells register
    their fallback story the same way.
    """
    _CHAINS[(kind, method, schedule)] = list(steps)


def _dispatch_derived(derived_plan, x, batch):
    """Run a derived plan through the engine's uniform batch layer."""
    from repro.core import engine as _engine

    fn = _engine.get_executor(derived_plan.kind, derived_plan.method,
                              derived_plan.schedule)
    return _engine.run_batched(fn, x, derived_plan, batch)


def _impl_step(impl: str) -> Step:
    def run(x, plan, batch):
        fault_point("resilience.step", step=f"impl:{impl}", kind=plan.kind,
                    method=plan.method, schedule=plan.schedule, impl=impl)
        return _dispatch_derived(
            dataclasses.replace(plan, impl=impl, mesh=None, strategy=None),
            x, batch)

    return Step(f"impl:{impl}", run)


def _method_step(method: str) -> Step:
    def run(x, plan, batch):
        fault_point("resilience.step", step=f"method:{method}",
                    kind=plan.kind, method=method, schedule="dense",
                    impl=None)
        block = plan.block if isinstance(plan.block, int) else 128
        derived = dataclasses.replace(
            plan, method=method, schedule="dense", impl=None,
            block=None if method == "dense" else block,
            block_z=None, z_chunk=None,
        )
        return _dispatch_derived(derived, x, batch)

    return Step(f"method:{method}", run)


def _select_step() -> Step:
    """Terminal rung of the knn cells: jnp cohesion with 'chunked'
    selection — unfused per-slab distances reduced by a row-chunked
    ``lax.top_k`` with host syncs between slabs (kernels/ops), the
    smallest machinery that still answers with identical semantics."""
    def run(x, plan, batch):
        fault_point("resilience.step", step="select:chunked", kind=plan.kind,
                    method=plan.method, schedule=plan.schedule, impl="jnp")
        derived = dataclasses.replace(plan, impl="jnp", select="chunked",
                                      mesh=None, strategy=None)
        return _dispatch_derived(derived, x, batch)

    return Step("select:chunked", run)


def _mesh_off_step() -> Step:
    """First rung of a mesh-sharded knn plan: re-enter the single-device
    fused select->cohere path.  The sharded bodies are bitwise-equal to the
    fused kernel by construction, so dropping the mesh degrades locality
    and wall-clock, never values."""
    def run(x, plan, batch):
        fault_point("resilience.step", step="mesh:single-device",
                    kind=plan.kind, method=plan.method,
                    schedule=plan.schedule, impl=plan.impl)
        derived = dataclasses.replace(plan, mesh=None, strategy=None)
        return _dispatch_derived(derived, x, batch)

    return Step("mesh:single-device", run)


def _reference_step() -> Step:
    def run(x, plan, batch):
        fault_point("resilience.step", step="reference", kind=plan.kind,
                    method=plan.method, schedule=plan.schedule, impl=None)
        if isinstance(x, jax.core.Tracer):
            raise FallbackUnavailable(
                "the numpy reference oracle needs concrete values; "
                "unavailable under jit/vmap tracing")
        from repro.core import reference as _reference
        from repro.core.weights import TIE_MODES

        # The numpy oracle only speaks the built-in tie modes.  For any
        # other registered weight functional, the terminal rung is the
        # un-blocked jnp einsum oracle (kernels/ref.py), which consumes
        # the SAME functional the failed executor did — a rescue must
        # never change the contribution algebra mid-request.
        builtin = plan.ties in TIE_MODES

        def one(xi):
            if plan.kind == "features":
                from repro.core.features import cdist_reference

                Di = np.asarray(
                    cdist_reference(jnp.asarray(xi, jnp.float32),
                                    metric=plan.metric))
            else:
                Di = np.asarray(xi)
            if builtin:
                C = _reference.pald_pairwise_reference(
                    Di, ties=plan.ties, normalize=plan.normalize)
            else:
                from repro.kernels import ref as _ref

                Dj = jnp.asarray(Di, jnp.float32)
                U = _ref.focus_ref(Dj, ties=plan.weight)
                C = _ref.cohesion_ref(Dj, _ref.weights_ref(U),
                                      ties=plan.weight)
                if plan.normalize:
                    C = C / max(Dj.shape[0] - 1, 1)
            return np.asarray(C, np.float32)

        xv = np.asarray(x)
        out = one(xv) if xv.ndim == 2 else np.stack([one(xi) for xi in xv])
        return jnp.asarray(out, jnp.float32)

    return Step("reference", run)


def _default_chain(plan) -> list:
    """pallas → interpret → jnp → blocked jnp methods → reference.

    Entries equal to the plan's own (failed) impl are skipped, as is
    ``pallas`` off-TPU (it cannot succeed there, so attempting it would
    only add latency to an already-failing call).  The knn cells walk the
    impls and then end on ``select:chunked`` — the row-chunked
    ``lax.top_k`` selection rung with jnp cohesion — rather than any
    dense method: no other registered path shares their sparse O(n·k²)
    semantics, and silently answering with the exact dense result would
    change cost by orders of magnitude mid-request.
    """
    steps: list[Step] = []
    if getattr(plan, "mesh", None) is not None:
        # a failed mesh cell rescues onto ONE device first — same impl,
        # same tiles, bitwise-identical answer, no collectives in the way
        steps.append(_mesh_off_step())
    if plan.method in ("kernel", "fused", "knn"):
        on_tpu = jax.default_backend() == "tpu"
        for impl in IMPL_ORDER:
            if impl == plan.impl:
                continue
            if impl == "pallas" and not on_tpu:
                continue
            steps.append(_impl_step(impl))
        if plan.method == "kernel":
            steps.append(_method_step("triplet"))
            steps.append(_method_step("dense"))
        elif plan.method == "fused":
            steps.append(_method_step("dense"))
        elif plan.method == "knn":
            if not (plan.impl == "jnp" and plan.select == "chunked"):
                steps.append(_select_step())
    elif plan.method in ("pairwise", "triplet"):
        steps.append(_method_step("dense"))
    if plan.method != "knn":
        steps.append(_reference_step())
    return steps


def chain_for(plan) -> list:
    """The degradation chain for a plan's cell: registered override if one
    exists, else the default built from the cell's method class."""
    key = (plan.kind, plan.method, plan.schedule)
    if key in _CHAINS:
        return list(_CHAINS[key])
    return _default_chain(plan)


# ---------------------------------------------------------------------------
# guarded execution (the on_error="fallback" path of PaldPlan.execute)
# ---------------------------------------------------------------------------
def _oom_floor_note(plan, cell, exc) -> None:
    plan._events.append(_event(
        cell=cell, cause="oom-floor", error=exc, fallback=None, retries=0,
        batch=1))
    warn_once(("oom-floor", cell),
              f"PaLD {cell}: still RESOURCE_EXHAUSTED at the batch retry "
              f"floor (batch=1); walking the degradation chain")


def _run_with_oom_retries(run, x, plan, batch, cell, label):
    """Call ``run(x, batch)``, halving ``batch`` on OOM down to 1.

    Returns (result, batch) so the caller can keep the degraded bound for
    subsequent attempts.  Non-OOM failures (and OOM at the floor, or on
    unbatched input where there is nothing to halve) propagate.
    """
    while True:
        try:
            return run(x, batch), batch
        except Exception as exc:  # noqa: BLE001 — the guard's whole job
            if not is_oom(exc) or x.ndim != 3:
                raise
            current = batch if batch is not None else int(x.shape[0])
            if current <= 1:
                _oom_floor_note(plan, cell, exc)
                raise
            batch = max(current // 2, 1)
            plan._events.append(_event(
                cell=cell, cause="oom", error=exc, fallback=None,
                retries=1, batch=batch))
            warn_once(("oom", cell),
                      f"PaLD {cell}: RESOURCE_EXHAUSTED on the batched "
                      f"call; retrying with batch={batch}")


def execute_plan(plan, x):
    """The fallback-mode execution path behind ``PaldPlan.execute``.

    Primary attempt first (with OOM-aware batch halving), then the
    degradation chain, each step under the same OOM guard.  The first step
    that succeeds records a degradation event and returns; exhaustion
    raises ``FallbackExhausted`` chained from the original failure.
    """
    from repro.core import engine as _engine

    cell = (plan.kind, plan.method, plan.schedule)
    batch = plan.batch

    def primary(xi, b):
        fault_point("engine.execute", kind=plan.kind, method=plan.method,
                    schedule=plan.schedule, impl=plan.impl)
        fn = _engine.get_executor(*cell)
        return _engine.run_batched(fn, xi, plan, b)

    try:
        result, _ = _run_with_oom_retries(primary, x, plan, batch, cell,
                                          "primary")
        return result
    except Exception as exc:  # noqa: BLE001 — the guard's whole job
        original = exc

    attempts: list[tuple[str, BaseException]] = [
        (f"primary({plan.impl or plan.method})", original)]
    for step in chain_for(plan):
        try:
            result, batch = _run_with_oom_retries(
                lambda xi, b, s=step: s.run(xi, plan, b), x, plan, batch,
                cell, step.label)
        except Exception as step_exc:  # noqa: BLE001
            attempts.append((step.label, step_exc))
            continue
        extra = {}
        if getattr(plan, "mesh", None) is not None:
            # record WHICH mesh cell failed so explain()["degradations"]
            # pins the rescue to a concrete (mesh shape, strategy) pair
            extra["mesh"] = tuple(plan.mesh.devices.shape)
            extra["strategy"] = plan.strategy
        plan._events.append(_event(
            cell=cell, cause="executor-failure", error=original,
            fallback=step.label, retries=len(attempts), **extra))
        warn_once(("fallback", cell, step.label),
                  f"PaLD {cell}: primary executor failed "
                  f"({type(original).__name__}: {original}); degraded to "
                  f"{step.label} — results keep identical "
                  f"ties/normalize semantics")
        return result

    tried = ", ".join(f"{label}: {type(e).__name__}" for label, e in attempts)
    raise FallbackExhausted(
        f"every fallback failed for cell {cell}: primary raised "
        f"{type(original).__name__}: {original}; degradation chain "
        f"attempted [{tried}]") from original


# ---------------------------------------------------------------------------
# guarded rectangular primitives (the distributed shard-body consumer)
# ---------------------------------------------------------------------------
def guarded_general(plan, what: str, call: Callable[[str | None], Any]):
    """Impl-degradation guard for ``plan.focus_general``/``cohesion_general``.

    The shard bodies call the rectangular kernels at trace time, so a
    Pallas lowering/compile failure is catchable here; the walk retries
    ``call`` with each remaining impl of ``IMPL_ORDER``.  The terminal
    reference oracle is NOT in this chain — these calls always run under
    ``shard_map`` tracing, where only traceable impls can answer.
    """
    cell = (plan.kind, plan.method, plan.schedule)
    effective = plan.impl or (
        "pallas" if jax.default_backend() == "tpu" else "jnp")
    try:
        return call(plan.impl)
    except Exception as exc:  # noqa: BLE001 — the guard's whole job
        original = exc
    attempts = [(f"impl:{effective}", original)]
    for impl in IMPL_ORDER:
        if impl == effective:
            continue
        if impl == "pallas" and jax.default_backend() != "tpu":
            continue
        try:
            result = call(impl)
        except Exception as step_exc:  # noqa: BLE001
            attempts.append((f"impl:{impl}", step_exc))
            continue
        plan._events.append(_event(
            cell=cell, cause=f"{what}-failure", error=original,
            fallback=f"impl:{impl}", retries=len(attempts)))
        warn_once((what, cell, impl),
                  f"PaLD shard body {what}: impl {effective!r} failed "
                  f"({type(original).__name__}); degraded to impl={impl!r}")
        return result
    tried = ", ".join(f"{label}: {type(e).__name__}" for label, e in attempts)
    raise FallbackExhausted(
        f"every fallback failed for shard-body {what} on cell {cell}: "
        f"degradation chain attempted [{tried}]") from original


# ---------------------------------------------------------------------------
# fault points (the injection substrate; armed only by repro.testing.faults)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FaultRule:
    """One armed fault.  Matching is AND over the given criteria:

    ``site``      substring of the fault-point name ("" matches all);
    ``match``     exact equality on context kwargs (e.g. impl="interpret");
    ``pred``      arbitrary predicate over (site=..., **ctx) — e.g. trip
                  only when the batch chunk exceeds a simulated memory cap;
    ``nth``       1-based matching-call index at which tripping starts
                  (nth=3: the first two matching calls pass untouched);
    ``times``     maximum number of trips (None = every matching call).

    ``exc`` is a zero-arg factory so each trip raises a fresh exception.
    """

    exc: Callable[[], BaseException]
    site: str = ""
    match: dict | None = None
    pred: Callable[..., bool] | None = None
    nth: int = 1
    times: int | None = None
    calls: int = 0
    trips: int = 0


_RULES: list[FaultRule] = []
_RULES_LOCK = threading.Lock()


def arm(rule: FaultRule) -> FaultRule:
    with _RULES_LOCK:
        _RULES.append(rule)
    return rule


def disarm(rule: FaultRule) -> None:
    with _RULES_LOCK:
        if rule in _RULES:
            _RULES.remove(rule)


def fault_point(site: str, **ctx) -> None:
    """A named, normally-inert injection site.

    Threaded through the engine dispatch (``engine.execute``,
    ``engine.batch``), every kernel entry point in ``repro.kernels.ops``,
    the feature front-end and each degradation-chain step.  Zero-cost when
    nothing is armed (one falsy check); when a ``FaultRule`` matches, the
    rule's exception is raised exactly as a real failure at that site
    would be.
    """
    if not _RULES:
        return
    with _RULES_LOCK:
        rules = list(_RULES)
    for rule in rules:
        if rule.site and rule.site not in site:
            continue
        if rule.match and any(ctx.get(k) != v for k, v in rule.match.items()):
            continue
        if rule.pred is not None and not rule.pred(site=site, **ctx):
            continue
        rule.calls += 1
        if rule.calls < rule.nth:
            continue
        if rule.times is not None and rule.trips >= rule.times:
            continue
        rule.trips += 1
        raise rule.exc()
