"""Serving steps: batched prefill and single-token decode with caches.

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run shapes
lower: one new token against a KV/SSM cache of ``seq_len``.  KV caches shard
over the kv-head dim when it divides the model axis, else over sequence
(emergent sequence-parallel decode; repro.sharding.partition.cache_pspec).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import Model, cast_floats
from repro.sharding import partition


def make_prefill_step(cfg: ModelConfig, *, q_chunk: int = 512):
    model = Model(cfg)

    def prefill_step(params, batch: dict, caches):
        p = cast_floats(params, jnp.bfloat16)
        if "embeds" in batch:
            b = {"embeds": batch["embeds"].astype(jnp.bfloat16)}
        else:
            b = {"tokens": batch["tokens"]}
        logits, caches = model.prefill(p, b, caches, q_chunk=q_chunk)
        return logits.astype(jnp.float32), caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = Model(cfg)

    def decode_step(params, token, caches, pos):
        p = cast_floats(params, jnp.bfloat16)
        if token.ndim == 3:
            token = token.astype(jnp.bfloat16)
        logits, caches = model.decode_step(p, token, caches, pos)
        return logits.astype(jnp.float32), caches

    return decode_step


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """NamedSharding tree matching Model.init_caches output."""
    bspec = partition.batch_pspec(mesh, batch)
    b = bspec[0] if bspec else None
    m = mesh.shape.get("model", 1)
    out = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            Sc = min(spec.window, max_len) if spec.window else max_len
            kv = cfg.n_kv_heads
            if kv % m == 0:
                kvspec = P(None, b, None, "model", None)
            elif Sc % m == 0:
                kvspec = P(None, b, "model", None, None)
            else:
                kvspec = P(None, b, None, None, None)
            out.append({
                "k": NamedSharding(mesh, kvspec),
                "v": NamedSharding(mesh, kvspec),
                "pos": NamedSharding(mesh, P(None)),
            })
        else:
            mm = cfg.mamba
            d_in = mm.expand * cfg.d_model
            H = d_in // mm.head_dim
            inner = "model" if d_in % m == 0 else None
            heads = "model" if H % m == 0 else None
            out.append({
                "conv_x": NamedSharding(mesh, P(None, b, None, inner)),
                "conv_B": NamedSharding(mesh, P(None, b, None, None)),
                "conv_C": NamedSharding(mesh, P(None, b, None, None)),
                "ssm": NamedSharding(mesh, P(None, b, heads, None, None)),
            })
    return tuple(out)
