"""Shared tie-handling predicates for every PaLD comparison tile.

On tie-heavy distances (integer metrics, quantized embeddings, duplicated
points) the pre-PR3 pipeline returned different cohesion matrices for the
same input depending on dispatch: the dense vectorized paths implemented
``ties='drop'``, the tri schedules implemented ``'ignore'`` for cross-block
pairs but ``'drop'`` inside diagonal blocks (the comparison-complement trick
only covers off-diagonal visits), and ``method="auto"`` silently picked
among them by size.  The fix is to implement the comparison predicate ONCE
— here — and have every tile body (blocked jnp, all Pallas kernels and
their fallbacks, the distributed shard bodies) call it, so all paths are
interchangeable for each mode (DESIGN.md §9).

Modes (``TIE_MODES``), for a pair (x, y) and third point z:

``'drop'`` (default)
    Strict ``<`` everywhere: a z with d_xz == d_yz inside the focus supports
    neither point — the branch-free vector analogue of the paper's "ignoring
    equality in distance comparisons", and the cheapest tile body.
``'split'``
    The theoretical formulation (and *Generalized partitioned local depth*,
    Berenhaut, Foley & Lyu 2023): exact ties split support 0.5/0.5.  Applied
    to BOTH passes — a z sitting exactly on the focus boundary
    (d_xz == d_xy or d_yz == d_xy) joins the focus with weight 0.5, and a
    support tie d_xz == d_yz splits its (possibly fractional) mass.  This is
    the only mode that conserves total cohesion mass exactly on arbitrary
    tied input (see tests/test_ties.py).
``'ignore'``
    Algorithm 1's sequential if/else: on a support tie the point with the
    LARGER global index wins (the else-branch assigns y, and the loop runs
    x < y).  Focus membership stays strict.  This mode needs an index
    tiebreak, threaded as ``own_wins`` / ``xwins`` below.

Both helpers take static python-string ``ties`` (they are called inside
jit'd / Pallas-traced bodies, so the branch specializes at trace time) and
broadcast like the comparisons they replace.

Key algebraic identity used throughout pass 2: with the half-step
``h(a, t) = 1 if a < t else 0.5 if a == t else 0``, the split-mode
contribution of z to the x role,  max(h(d_xz,d_xy), h(d_yz,d_xy)) * share_x,
equals  share_x * h(d_xz, d_xy)  — the membership factor collapses to the
role's OWN comparison (if x gets any share, d_xz <= d_yz, which caps
h(d_yz, d_xy) at h(d_xz, d_xy)).  That keeps every per-role tile body in
its existing (d_own, d_other, d_pair) shape.
"""
from __future__ import annotations

import jax.numpy as jnp

TIE_MODES = ("drop", "split", "ignore")
DEFAULT_TIES = "drop"

__all__ = ["TIE_MODES", "DEFAULT_TIES", "validate_ties", "focus_weight",
           "support_weight", "index_xwins", "square_xwins"]


def validate_ties(ties: str) -> str:
    if ties not in TIE_MODES:
        raise ValueError(f"unknown ties mode {ties!r} (expected one of {TIE_MODES})")
    return ties


def focus_weight(dxz, dyz, dxy, ties: str = DEFAULT_TIES):
    """Pass-1 membership weight of z in the (x, y) local focus.

    Strict modes ('drop', 'ignore'): the usual indicator
    ``(d_xz < d_xy) | (d_yz < d_xy)`` as float32.  'split': boundary ties
    join with weight 0.5, i.e. ``max(h(d_xz, d_xy), h(d_yz, d_xy))`` with
    the half-step h — so U becomes fractional (multiples of 0.5, exact in
    f32).  Arguments broadcast together; +inf padding stays exact in every
    mode (inf == finite is false, inf == inf only happens for padded z
    against padded pairs whose weight is masked to zero anyway).
    """
    strict = (dxz < dxy) | (dyz < dxy)
    if ties != "split":
        return strict.astype(jnp.float32)
    eq = (dxz == dxy) | (dyz == dxy)
    return jnp.where(strict, 1.0, jnp.where(eq, 0.5, 0.0)).astype(jnp.float32)


def support_weight(d_own, d_other, d_pair, ties: str = DEFAULT_TIES,
                   own_wins=None):
    """Pass-2 weight with which z supports the 'own' point of a pair.

    For the x role of pair (x, y): ``d_own = d_xz``, ``d_other = d_yz``,
    ``d_pair = d_xy`` — i.e. exactly the three comparands of the classic
    strict tile body ``(d_xz < d_yz) & (d_xz < d_xy)``.  The y role swaps
    own/other.  Multiply the result by W[x, y] and accumulate.

    ``own_wins``: boolean array (broadcastable), true where the own point's
    GLOBAL index exceeds the partner's; required for ``ties='ignore'``
    (square kernels derive it from grid position, rectangular/distributed
    callers pass it explicitly as ``xwins``).
    """
    lt = d_own < d_other
    memb = d_own < d_pair
    if ties == "drop":
        return (lt & memb).astype(jnp.float32)
    if ties == "ignore":
        if own_wins is None:
            raise ValueError("ties='ignore' needs own_wins (index tiebreak)")
        return ((lt | ((d_own == d_other) & own_wins)) & memb).astype(jnp.float32)
    # split: share of the own-vs-other comparison times the half-step
    # membership in the own-vs-pair comparison (see module docstring)
    share = lt.astype(jnp.float32) + 0.5 * (d_own == d_other).astype(jnp.float32)
    half = memb.astype(jnp.float32) + 0.5 * (d_own == d_pair).astype(jnp.float32)
    return share * half


def index_xwins(row_off, nrows: int, col_off, ncols: int) -> jnp.ndarray:
    """(nrows, ncols) boolean 'global x index > global y index' tiebreak —
    THE definition of the ``ties='ignore'`` index convention, shared by the
    blocked square paths (offsets = block coordinates × tile) and the
    distributed bodies (offsets = device row offsets, possibly traced).
    The tri Pallas kernel body inlines the same ``>`` per y row to avoid
    materializing the tile."""
    rows = row_off + jnp.arange(nrows)
    cols = col_off + jnp.arange(ncols)
    return rows[:, None] > cols[None, :]


def square_xwins(n: int) -> jnp.ndarray:
    """(n, n) tiebreak for the square sequential case — what
    ``ties='ignore'`` feeds the rectangular kernel forms."""
    return index_xwins(0, n, 0, n)
