"""llama3.2-3b — 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    rope_theta=500000.0,
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=4,
    subquadratic=False,
)
