"""Candidate-grid block-size autotuner with a persistent JSON cache.

The paper's headline constant factors come from cache blocking with *tuned*
block sizes, and the optimum moves with n, the pass, and the backend — yet
every kernel entry point used to hard-code ``block=128, block_z=512``.  This
module is the single source of truth instead (DESIGN.md §Tuning):

* a JSON on-disk cache keyed by ``(backend, impl, n, pass)`` holding the
  measured-best ``(block, block_z)`` plus the full timing grid;
* ``resolve_blocks`` — the cheap consumer behind ``block="auto"`` in
  ``core.pald``, ``kernels.ops`` and ``core.distributed``: exact cache hit,
  else nearest-n hit (log-space) for the same key prefix, else a size-aware
  heuristic.  Never measures; always fast enough to call at trace time.
* ``tune`` — the producer: times a candidate grid for one ``(n, pass, impl)``
  cell and records the winner.  Driven by ``benchmarks/hillclimb.py blocks``
  so tuning results persist instead of being printed and forgotten.
* ``tune_methods`` / ``method_for`` — the same pattern one level up:
  measured method crossovers (dense vs triplet vs kernel) replacing the old
  hard-coded ``n <= 256`` heuristic in ``pald.cohesion(method="auto")``.

Cache location: ``$REPRO_TUNE_CACHE`` or ``~/.cache/repro_pald/blocktune.json``.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
import warnings
from typing import Iterable, Sequence

import numpy as np

try:  # POSIX only; the save lock degrades to plain atomic writes without it
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platform
    fcntl = None

_CACHE_ENV = "REPRO_TUNE_CACHE"
_MEM: dict[str, tuple[float, dict]] = {}  # abspath -> (mtime, data)
_QUARANTINE_WARNED: set[str] = set()  # abspaths that already warned

# passes understood by `tune`; each maps to one kernel-pipeline entry point
PASSES = ("focus", "cohesion", "focus_tri", "cohesion_tri", "pald",
          "pald_tri", "pald_fused", "pald_knn", "pald_topk")


# the three built-in tie modes (mirrors core/weights.TIE_MODES; duplicated
# here because importing repro.core from this module would cycle through
# repro.core.__init__ -> engine -> repro.tuning at import time)
_TIE_MODES = ("drop", "split", "ignore")


def _pass_key(pass_: str, d: int | None, ties=None,
              k: int | None = None, p: int | None = None) -> str:
    """Feature-fused cells depend on the feature dimension too: the optimal
    tile moves with d (the in-register distance compute scales with it), so
    d joins the cache key as a ``:d<d>`` suffix on the pass name.  The
    sparse knn pass depends on the neighborhood size the same way (the
    (block, k, k) tile scales with k^2), keyed ``:k<k>``.  Non-default
    weight functionals change the tile bodies (extra equality masks for
    'split', the index-tiebreak input for 'ignore', transcendentals for the
    smooth families), so they get their own cells: the built-in tie modes
    keep their legacy ``:t-<mode>`` suffix (existing caches stay valid, and
    the default 'drop' keeps the bare key), every other functional — by
    registered name or instance — gets ``:w-<name>`` so autotuned tiles
    never leak across functionals.

    The selection pass is keyed ``pald_topk:k<k>:d<d>`` — k first (it
    bounds the best-list/network width, the stronger lever) — and takes
    no ties suffix: neighbor selection is weight-independent.  Mesh-sharded
    selection appends ``:p<p>`` (the device count): the optimal row slab
    shrinks with the per-shard row count, so tiles tuned on one mesh shape
    never leak onto another; a ``:p`` miss falls back to the single-device
    cell of the same (k, d) before the size heuristic."""
    if pass_ == "pald_topk":
        if k is not None:
            pass_ = f"{pass_}:k{int(k)}"
        if d is not None:
            pass_ = f"{pass_}:d{int(d)}"
        if p is not None and int(p) > 1:
            pass_ = f"{pass_}:p{int(p)}"
        return pass_
    if d is not None:
        pass_ = f"{pass_}:d{int(d)}"
    if k is not None:
        pass_ = f"{pass_}:k{int(k)}"
    name = getattr(ties, "name", ties)
    if name and name != "drop":
        tag = "t-" if name in _TIE_MODES else "w-"
        pass_ = f"{pass_}:{tag}{name}"
    return pass_


def cache_path(path: str | None = None) -> str:
    if path:
        return path
    env = os.environ.get(_CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_pald",
                        "blocktune.json")


def _key(backend: str, impl: str, n: int, pass_: str) -> str:
    return f"{backend}|{impl}|{int(n)}|{pass_}"


def _split_key(key: str) -> tuple[str, str, int, str]:
    backend, impl, n, pass_ = key.split("|")
    return backend, impl, int(n), pass_


def _quarantine(p: str, exc: Exception) -> str | None:
    """Move a corrupt cache aside to ``<path>.corrupt-<ts>`` and warn once.

    A truncated/garbled JSON must not be silently treated as an empty
    cache forever — the corrupt bytes are preserved for inspection, the
    path starts fresh, and the one warning names both."""
    dest = f"{p}.corrupt-{time.strftime('%Y%m%dT%H%M%S')}"
    try:
        os.replace(p, dest)
    except OSError:  # racing writer already replaced it; nothing to move
        dest = None
    if p not in _QUARANTINE_WARNED:
        _QUARANTINE_WARNED.add(p)
        where = f"; corrupt file preserved at {dest}" if dest else ""
        warnings.warn(
            f"tuning cache {p} is corrupt ({type(exc).__name__}: {exc}); "
            f"starting a fresh cache{where}", stacklevel=3)
    return dest


def _read_cache_file(p: str) -> dict:
    """One fresh read of the cache file (no mtime memo): {} when missing,
    quarantine + {} when corrupt."""
    try:
        with open(p) as f:
            data = json.load(f)
        if not isinstance(data, dict):
            raise ValueError(
                f"expected a JSON object of records, got "
                f"{type(data).__name__}")
    except OSError:
        return {}
    except ValueError as exc:
        _quarantine(p, exc)
        return {}
    return data


def load_cache(path: str | None = None) -> dict:
    p = os.path.abspath(cache_path(path))
    try:
        mtime = os.path.getmtime(p)
    except OSError:
        return {}
    hit = _MEM.get(p)
    if hit and hit[0] == mtime:
        return hit[1]
    data = _read_cache_file(p)
    try:  # the quarantine may have moved the file away
        _MEM[p] = (os.path.getmtime(p), data)
    except OSError:
        _MEM.pop(p, None)
    return data


@contextlib.contextmanager
def _save_lock(p: str, timeout: float):
    """Exclusive advisory lock on ``<path>.lock`` for the save RMW cycle.

    Yields True when the lock is held.  On a non-POSIX platform (no fcntl)
    or when ``timeout`` expires (a peer died holding the lock, or is
    tuning a pathologically slow cell) the save proceeds UNLOCKED with a
    warning — losing a peer's concurrent entry beats deadlocking the
    tuner.  The sidecar (never the data file) is locked so the atomic
    ``os.replace`` of the data never invalidates anyone's lock fd."""
    if fcntl is None:
        yield False
        return
    with open(p + ".lock", "w") as lf:
        deadline = time.monotonic() + timeout
        while True:
            try:
                fcntl.flock(lf, fcntl.LOCK_EX | fcntl.LOCK_NB)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    warnings.warn(
                        f"could not lock tuning cache {p} within {timeout}s; "
                        "saving without the lock (a concurrent writer's "
                        "entry may be lost)", stacklevel=4)
                    yield False
                    return
                time.sleep(0.02)
        try:
            yield True
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


def save_entry(backend: str, impl: str, n: int, pass_: str, record: dict,
               path: str | None = None, *, lock_timeout: float = 10.0) -> str:
    """Merge one record into the cache (atomic write); returns the key.

    The read-modify-write cycle runs under an ``fcntl`` lock and re-reads
    the file fresh inside it, so two concurrent tuners (e.g. parallel
    ``hillclimb`` processes) merge instead of losing each other's rows.
    """
    p = os.path.abspath(cache_path(path))
    key = _key(backend, impl, n, pass_)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with _save_lock(p, lock_timeout):
        data = _read_cache_file(p)  # fresh under the lock: merge, not clobber
        data[key] = record
        tmp = f"{p}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, p)
    _MEM[p] = (os.path.getmtime(p), data)
    return key


def lookup(backend: str, impl: str, n: int, pass_: str,
           path: str | None = None) -> dict | None:
    return load_cache(path).get(_key(backend, impl, n, pass_))


def lookup_nearest(backend: str, impl: str, n: int, pass_: str,
                   path: str | None = None) -> tuple[int, dict] | None:
    """Nearest-n cache entry (log-space) for the same (backend, impl, pass)."""
    best = None
    for key, rec in load_cache(path).items():
        try:
            b, i, kn, kp = _split_key(key)
        except ValueError:
            continue
        if (b, i, kp) != (backend, impl, pass_) or kn <= 0:
            continue
        dist = abs(np.log(kn) - np.log(max(n, 1)))
        if best is None or dist < best[0]:
            best = (dist, kn, rec)
    if best is None:
        return None
    return best[1], best[2]


def _default_backend() -> str:
    import jax
    return jax.default_backend()


def _default_impl(backend: str) -> str:
    return "pallas" if backend == "tpu" else "jnp"


def _valid_tile(v) -> bool:
    """A usable cached tile: an integral number > 0 (bool excluded).  A
    hand-edited or bit-flipped cache must degrade to defaults at lookup,
    never raise mid-``plan()``."""
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return False
    return float(v) == int(v) and int(v) > 0


def _default_blocks(n: int, pass_: str) -> tuple[int, int]:
    """Size-aware fallback when nothing is cached (the old constants,
    clamped).  cohesion_tri keeps its whole (n, block_z) column slab in
    VMEM, so its z tile shrinks as n grows (~6 MiB budget).  The
    selection pass (pald_topk) defaults to the PR 5 contract — 1024-row
    slabs, tile = n i.e. direct full-width top_k (the tile-min prefilter
    must be opted in or measured in; on clustered data direct wins)."""
    if pass_ == "pald_topk":
        return max(min(1024, n), 1), max(n, 1)
    block = min(128, n)
    block_z = min(512, n)
    if pass_ == "cohesion_tri" and n > 0:
        block_z = min(block_z, max((6 << 20) // (4 * n), 8))
    return max(block, 1), max(block_z, 1)


def resolve_blocks_ex(
    n: int,
    pass_: str,
    *,
    impl: str | None = None,
    backend: str | None = None,
    path: str | None = None,
    d: int | None = None,
    ties=None,
    k: int | None = None,
    p: int | None = None,
) -> tuple[int, int, str]:
    """(block, block_z, source) for one pass at size n.

    ``source`` records the provenance for ``PaldPlan.explain()``:
    ``"cache:<key>"`` exact hit, ``"nearest:<key>@n=<kn>"`` nearest-n hit
    (log-space), ``"default"`` size-aware heuristic (cold cache).

    ``d`` (feature dimension) extends the key for the fused pass — tiles
    tuned at one d are not reused for another; ``k`` does the same for the
    sparse knn pass (``pald_knn:k<k>``).  ``ties`` (a mode string, a
    registered functional name, or a ``WeightFunctional`` instance) extends
    the key for every non-default functional (their tile bodies differ); a
    miss on such a cell falls back to the strict cell's entry before the
    size heuristic, since the optima rarely move much.  ``p`` (mesh device
    count) extends the selection-pass key (``pald_topk:...:p<p>``); a miss
    on the mesh cell falls back to the single-device cell the same way."""
    backend = backend or _default_backend()
    impl = impl or _default_impl(backend)
    base = _pass_key(pass_, d, k=k)
    keyed = _pass_key(pass_, d, ties, k=k)
    meshed = _pass_key(pass_, d, ties, k=k, p=p)
    quarantined = None
    # mesh cell first, then the tie-mode cell, then strict single-device
    for pk in dict.fromkeys((meshed, keyed, base)):
        rec = lookup(backend, impl, n, pk, path)
        key = _key(backend, impl, n, pk)
        source = f"cache:{key}"
        if rec is None:
            near = lookup_nearest(backend, impl, n, pk, path)
            if near:
                rec = near[1]
                key = _key(backend, impl, near[0], pk)
                source = f"nearest:{key}"
        if isinstance(rec, dict) and "block" in rec:
            bz_rec = rec.get("block_z", rec["block"])
            if _valid_tile(rec["block"]) and _valid_tile(bz_rec):
                return (max(min(int(rec["block"]), n), 1),
                        max(min(int(bz_rec), n), 1),
                        source)
            # wrong-typed / non-positive tiles: fall through to defaults
            # with the quarantine provenance instead of raising mid-plan()
            quarantined = quarantined or f"quarantined:{key}"
        elif rec is not None:
            quarantined = quarantined or f"quarantined:{key}"
    b, bz = _default_blocks(n, pass_)
    return b, bz, quarantined or "default"


def resolve_blocks(
    n: int,
    pass_: str,
    *,
    impl: str | None = None,
    backend: str | None = None,
    path: str | None = None,
    d: int | None = None,
    ties=None,
    k: int | None = None,
    p: int | None = None,
) -> tuple[int, int]:
    """(block, block_z) for one pass at size n: cached, nearest, or default.

    Thin wrapper over ``resolve_blocks_ex`` (which also reports the
    provenance of the answer)."""
    b, bz, _ = resolve_blocks_ex(n, pass_, impl=impl, backend=backend,
                                 path=path, d=d, ties=ties, k=k, p=p)
    return b, bz


def resolve_fused_tiles(
    n: int,
    d: int,
    block,
    block_z,
    *,
    impl: str | None = None,
    backend: str | None = None,
    ties=None,
    path: str | None = None,
) -> tuple[int, int, str | None]:
    """The fused pipeline's tile defaults, in exactly one place.

    ``block_z=None`` rides along with ``block`` ("auto" together, else the
    512 legacy default); "auto" resolves under the ``pald_fused`` pass keyed
    by (n, d, ties); both tiles clamp to n.  Shared by ``engine.plan`` and
    ``kernels.ops.pald_fused`` so the resolved plan can never drift from
    what the kernel entry point would have computed itself.

    Returns (block, block_z, source) — ``source`` is the cache provenance
    string when any "auto" was resolved, else None (fully explicit tiles).
    """
    if block_z is None:
        block_z = "auto" if block == "auto" else 512
    source = None
    if block == "auto" or block_z == "auto":
        rb, rbz, source = resolve_blocks_ex(
            n, "pald_fused", impl=impl, backend=backend, d=d, ties=ties,
            path=path)
        block = rb if block == "auto" else block
        block_z = rbz if block_z == "auto" else block_z
    return min(int(block), n), min(int(block_z), n), source


# ---------------------------------------------------------------------------
# measurement (producer side)
# ---------------------------------------------------------------------------
def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready.

    The single timing discipline shared by the tuner and the benchmark
    suite (``benchmarks.common`` re-exports this)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def random_distance_matrix(n: int, seed: int = 0, dim: int = 8) -> np.ndarray:
    """Euclidean distances of gaussian points (tie-free w.h.p.)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)).astype(np.float32)
    np.fill_diagonal(D, 0.0)
    return D


def random_features(n: int, d: int = 8, seed: int = 0) -> np.ndarray:
    """Gaussian feature matrix (the fused pass's measurement input)."""
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _synthetic_inputs(n: int, seed: int = 0, with_weights: bool = False,
                      d: int = 8, with_distances: bool = True):
    """(D, W, X) measurement inputs; W only when the pass consumes it (built
    with the chunked kernel pipeline, never the O(n^3)-memory reference).
    ``with_distances=False`` (the fused pass) skips the O(n^2) D entirely —
    materializing it is exactly what that pass exists to avoid."""
    import jax.numpy as jnp
    X = jnp.asarray(random_features(n, d, seed))
    if not with_distances:
        return None, None, X
    D = jnp.asarray(random_distance_matrix(n, seed, dim=d), jnp.float32)
    W = None
    if with_weights:
        from repro.kernels import ops, ref
        W = ref.weights_ref(ops.focus(D, impl=None if ops.on_tpu() else "jnp"))
    return D, W, X


def _runner(pass_: str, D, W, X, block: int, block_z: int, impl: str,
            ties="drop", k: int | None = None, p: int | None = None):
    from repro.kernels import ops
    if pass_ == "pald_knn":
        return ops.pald_knn(D, k=k or 16, block=block, impl=impl,
                            ties=ties)[1]
    if pass_ == "pald_topk":
        if p is not None and p > 1:
            # mesh cell: time the sharded select->cohere body itself on a
            # p-device row shard — block/tile mean exactly what the
            # pald_knn_sharded consumer passes them as, so the argmin is
            # measured where it will be spent
            from repro.core import distributed_knn as dknn
            from repro.launch import mesh as meshlib
            m = meshlib.make_test_mesh((p,), ("data",))
            return dknn.pald_knn_sharded(X, m, k=k or 16, block=block,
                                         tile=block_z)[1]
        # block = rows per slab, block_z = tile-min prefilter width
        # (>= n means direct); candidates time the full selection entry
        return ops.topk_select(X, k or 16, impl=impl, block=block,
                               tile=block_z).distances
    if pass_ == "focus":
        return ops.focus_general(D, D, D, block=block, block_z=block_z,
                                 impl=impl, ties=ties)
    if pass_ == "focus_tri":
        return ops.focus(D, block=block, block_z=block_z, impl=impl,
                         schedule="tri", ties=ties)
    if pass_ == "cohesion":
        return ops.cohesion_from_weights(D, W, block=block, block_z=block_z,
                                         impl=impl, ties=ties)
    if pass_ == "cohesion_tri":
        return ops.cohesion_from_weights(D, W, block=block, block_z=block_z,
                                         impl=impl, schedule="tri", ties=ties)
    if pass_ == "pald":
        return ops.pald(D, block=block, block_z=block_z, impl=impl, ties=ties)
    if pass_ == "pald_tri":
        return ops.pald_tri(D, block=block, block_z=block_z, impl=impl,
                            ties=ties)
    if pass_ == "pald_fused":
        return ops.pald_fused(X, block=block, block_z=block_z, impl=impl,
                              ties=ties)
    raise ValueError(f"unknown pass {pass_!r} (expected one of {PASSES})")


def tune(
    n: int,
    pass_: str,
    *,
    impl: str | None = None,
    backend: str | None = None,
    blocks: Iterable[int] = (32, 64, 128, 256, 512),
    blocks_z: Iterable[int] = (128, 256, 512, 1024),
    path: str | None = None,
    save: bool = True,
    seed: int = 0,
    iters: int = 3,
    d: int | None = None,
    ties="drop",
    k: int | None = None,
    p: int | None = None,
    time_budget: float | None = None,
) -> dict:
    """Measure the candidate grid for one (n, pass, impl) cell and record the
    argmin.  Returns the record that was (or would be) cached.

    For ``pass_="pald_fused"`` the feature dimension ``d`` (default 8) joins
    the cache key — the fused tiles trade in-register distance compute
    against revisit traffic, and that tradeoff moves with d.  For
    ``pass_="pald_knn"`` the neighborhood size ``k`` (default 16) joins it
    the same way (``pald_knn:k<k>``); that pass has no z tile, so only the
    row-block axis of the grid is swept.  Non-default ``ties`` modes are
    keyed separately too (their tile bodies differ).

    ``pass_="pald_topk"`` (streaming neighbor selection) is keyed
    ``pald_topk:k<k>:d<d>`` with no ties suffix (selection is
    weight-independent); its grid sweeps the selection row slab
    (``blocks``) against the tile-min prefilter width (``blocks_z``,
    where a candidate >= n means the direct full-width top_k) — the
    prefilter-vs-direct crossover is data- and k-dependent, which is
    exactly why it is measured, not hardcoded.  With ``p`` > 1 the cell
    is the MESH cell (key gains ``:p<p>``): candidates time the sharded
    select->cohere body on a p-device row shard, so the cached
    (block, tile) is measured exactly where ``pald_knn_sharded``'s
    ``block="auto"`` will spend it; requires p forced/real devices.

    The sweep is guarded per candidate: a crashing candidate records a
    ``{"failed": True, "error": ...}`` row and the grid continues; once
    ``time_budget`` (wall seconds for the whole sweep, checked between
    candidates — a single in-flight measurement cannot be preempted)
    is exceeded, remaining candidates record ``{"skipped": "over-budget"}``
    rows.  The argmin is taken over the successful rows only; if every
    candidate failed, RuntimeError (nothing worth caching)."""
    backend = backend or _default_backend()
    impl = impl or _default_impl(backend)
    if p is not None and p > 1:
        if pass_ != "pald_topk":
            raise ValueError(
                f"p= (mesh device count) only keys the selection pass "
                f"(pald_topk), not {pass_!r}")
        import jax
        if p > len(jax.devices()):
            raise RuntimeError(
                f"tuning the p={p} mesh cell needs {p} devices, have "
                f"{len(jax.devices())} (force host devices via "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={p})")
    if pass_ in ("pald_fused", "pald_topk") and d is None:
        d = 8
    if pass_ == "pald_knn":
        k = k or 16
        blocks_z = (0,)  # no z tile: don't re-time identical cells
    if pass_ == "pald_topk":
        # k-dependent tiles: the row-slab grid scales with the slab cost,
        # blocks_z doubles as the tile-min prefilter width (n = direct)
        k = k or 16
        blocks = tuple(blocks) if tuple(blocks) != (32, 64, 128, 256, 512) \
            else (256, 512, 1024, 2048)
        blocks_z = tuple(blocks_z) if tuple(blocks_z) != (128, 256, 512, 1024) \
            else (32, 64, 128, n)
    D, W, X = _synthetic_inputs(
        n, seed, with_weights=pass_ in ("cohesion", "cohesion_tri"),
        d=d if d is not None else 8,
        with_distances=pass_ not in ("pald_fused", "pald_topk"),
    )
    rows = []
    t0 = time.monotonic()
    over_budget = False
    for b in sorted({min(b, n) for b in blocks}):
        for bz in sorted({min(z, n) for z in blocks_z}):
            if over_budget:
                rows.append({"block": b, "block_z": bz,
                             "skipped": "over-budget"})
                continue
            try:
                t = time_fn(
                    lambda: _runner(pass_, D, W, X, b, bz, impl, ties, k, p),
                    iters=iters)
            except Exception as exc:  # noqa: BLE001 - one bad candidate
                rows.append({"block": b, "block_z": bz, "failed": True,
                             "error": f"{type(exc).__name__}: {exc}"})
            else:
                rows.append({"block": b, "block_z": bz,
                             "seconds": round(t, 6)})
            if time_budget is not None and time.monotonic() - t0 > time_budget:
                over_budget = True
    ok = [r for r in rows if "seconds" in r]
    if not ok:
        raise RuntimeError(
            f"every candidate failed for (n={n}, pass={pass_!r}, "
            f"impl={impl!r}); first error: "
            f"{next(r['error'] for r in rows if r.get('failed'))}")
    best = min(ok, key=lambda r: r["seconds"])
    record = {
        "block": best["block"],
        "block_z": best["block_z"],
        "seconds": best["seconds"],
        "grid": rows,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    if save:
        save_entry(backend, impl, n,
                   _pass_key(pass_,
                             d if pass_ in ("pald_fused", "pald_topk")
                             else None,
                             None if pass_ == "pald_topk" else ties,
                             k=k if pass_ in ("pald_knn", "pald_topk")
                             else None,
                             p=p if pass_ == "pald_topk" else None),
                   record, path)
    return record


# ---------------------------------------------------------------------------
# method crossovers (dense / pairwise / triplet / kernel schedules)
# ---------------------------------------------------------------------------
_METHOD_IMPL = "-"  # methods span impls; keyed under a fixed placeholder


def tune_methods(
    ns: Sequence[int] = (64, 128, 256, 512, 1024),
    methods: Sequence[str] = ("dense", "pairwise", "triplet"),
    *,
    backend: str | None = None,
    path: str | None = None,
    save: bool = True,
    iters: int = 3,
) -> list[dict]:
    """Measure pald.cohesion per method across n; record the per-n winner so
    method="auto" uses observed crossovers instead of a magic constant."""
    from repro.core import pald
    backend = backend or _default_backend()
    out = []
    for n in ns:
        D, _, _X = _synthetic_inputs(n)
        timings, failed = {}, {}
        for m in methods:
            try:
                timings[m] = round(
                    time_fn(lambda: pald.cohesion(D, method=m), iters=iters),
                    6)
            except Exception as exc:  # noqa: BLE001 - one bad method
                failed[m] = f"{type(exc).__name__}: {exc}"
        if not timings:
            raise RuntimeError(
                f"every method failed at n={n}: {failed}")
        best = min(timings, key=timings.get)
        record = {"method": best, "timings": timings,
                  "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S")}
        if failed:
            record["failed"] = failed
        if save:
            save_entry(backend, _METHOD_IMPL, n, "method", record, path)
        out.append({"n": n, **record})
    return out


def method_for_ex(n: int, *, backend: str | None = None,
                  path: str | None = None) -> tuple[str, str]:
    """(method, source) at size n — the provenance-reporting sibling of
    ``method_for`` (source: "cache:<key>" / "nearest:<key>@..." /
    "heuristic")."""
    backend = backend or _default_backend()
    rec = lookup(backend, _METHOD_IMPL, n, "method", path)
    key = _key(backend, _METHOD_IMPL, n, "method")
    source = f"cache:{key}"
    if rec is None:
        near = lookup_nearest(backend, _METHOD_IMPL, n, "method", path)
        if near:
            rec = near[1]
            key = _key(backend, _METHOD_IMPL, near[0], "method")
            source = f"nearest:{key}"
    fallback = "dense" if n <= 256 else "triplet"
    if rec is None:
        return fallback, "heuristic"
    # auto-selectable methods only: an edited/corrupted record must not
    # make plan() pick knn (needs k=) or an unknown string — fall to the
    # heuristic with quarantine provenance instead of raising mid-plan()
    m = rec.get("method") if isinstance(rec, dict) else None
    if m in ("dense", "pairwise", "triplet", "kernel"):
        return str(m), source
    return fallback, f"quarantined:{key}"


def method_for(n: int, *, backend: str | None = None,
               path: str | None = None) -> str:
    """Best cohesion method at size n: measured crossover if available,
    else the seed heuristic (dense small, triplet large)."""
    return method_for_ex(n, backend=backend, path=path)[0]
