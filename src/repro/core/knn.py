"""Sparse k-NN PaLD: neighborhood selection, struct, and tile semantics.

The triplet-comparison algorithms of the source paper are inherently
O(n^3)-work / O(n^2)-memory — at n = 50k the distance matrix alone is
10 GiB and the comparison count is 1.25e14, which caps the dense pipeline
at a few tens of thousands of points.  *Partitioned K-nearest neighbor
local depth* (Baron, Darling, Davis & Pfeifer, arXiv:2108.08864) shows
that PaLD restricted to k-nearest-neighbor conflict foci preserves the
community structure the full computation finds, at O(n * k^2) cost.  This
module is that restriction, engineered to the same contracts as every
dense path (shared ``core/weights.py`` weight functionals,
engine-registered executor, tuning-cache tiles):

``NeighborGraph``
    The CSR-style neighborhood struct: ``indices (n, k)`` int32 and
    ``distances (n, k)`` float32, row ``x`` holding x's k nearest other
    points sorted by (distance, index).  A NamedTuple, so it is a pytree
    and traces through ``jit`` / ``vmap`` unchanged.

``knn_from_distances(D, k)`` / ``knn_from_features(X, k, metric=...)``
    Top-k selection from a precomputed matrix or — chunked, never
    materializing D — straight from feature vectors.  Tie-break at the
    k boundary is deterministic: equal distances admit the LOWER index
    first (``jax.lax.top_k``'s stable order on the negated distances).

``knn_values_tile(dn, g, own_wins, ties)``
    The exact-within-neighborhood PaLD semantics for one row tile — the
    single tile body shared by the blocked-jnp fallback
    (``kernels/ops._knn_values_jnp``) and the Pallas kernel
    (``kernels/pald_knn.py``), the same way ``core/weights.py`` is shared
    by every dense tile body.

``scatter_dense(graph, values)``
    Expand the sparse (n, k+1) cohesion values into the dense (n, n) C
    the rest of the API speaks — the ``method="knn"`` executors end with
    this; large-n consumers keep the sparse form instead.

Semantics (what ``method="knn"`` approximates)
----------------------------------------------
For every DIRECTED conflict pair (x, y) with y in N_k(x), the conflict
focus is restricted to the candidate set {x} ∪ N_k(x) (which contains y
by construction), and only the x role accumulates support:

    U_k[x, y] = sum_{z in {x} ∪ N_k(x)} focus_weight(d_xz, d_yz, d_xy)
    C[x, z]  += support_weight(d_xz, d_yz, d_xy) / U_k[x, y]

with the focus/support contributions — and therefore the ``ties=`` /
``weight=`` contract — taken verbatim from ``core/weights.py``.  Row x of
C is supported only at
z in {x} ∪ N_k(x), which is exactly the sparse (n, k+1) value layout.

At k = n-1 the candidate set is all n points and the directed pair sum
ranges over every ordered pair, so the restriction is the identity and
U_k, C coincide with the dense definition (asserted in the conformance
matrix; the engine executor runs the dense path outright there, see
``kernels/ops.pald_knn``).  For k < n-1 the directed formulation keeps
each row's computation local to its own neighborhood — O(k^2) work and
O(k^2) gathered distances per point, no cross-row reduction — which is
what makes the single-pass (block, k) kernel schedule possible.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .weights import (DEFAULT_TIES, focus_weight, resolve_weight,
                      support_weight)

__all__ = [
    "NeighborGraph",
    "knn_from_distances",
    "knn_from_features",
    "knn_values_tile",
    "scatter_dense",
    "local_depths",
    "universal_threshold",
    "strong_ties",
    "communities",
]


class NeighborGraph(NamedTuple):
    """k-nearest-neighbor structure of n points (a jit-friendly pytree).

    Attributes:
        indices: (n, k) int32 — row x holds the indices of x's k nearest
            OTHER points (self always excluded), ordered by increasing
            distance with exact ties broken toward the lower index.
        distances: (n, k) float32 — the matching distances, so
            ``distances[x, j] == d(x, indices[x, j])``.
    """

    indices: jnp.ndarray
    distances: jnp.ndarray

    @property
    def n(self) -> int:
        return self.indices.shape[0]

    @property
    def k(self) -> int:
        return self.indices.shape[1]


def _top_k_rows(neg_rows: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(distances, indices) of the k smallest entries per row of -neg_rows.

    ``lax.top_k`` is stable (equal values surface the lower index first),
    which on negated distances yields the deterministic tie-break the
    whole knn contract relies on."""
    vals, idx = jax.lax.top_k(neg_rows, k)
    return -vals, idx.astype(jnp.int32)


def knn_from_distances(D: jnp.ndarray, k: int) -> NeighborGraph:
    """Select each point's k nearest neighbors from a distance matrix.

    Args:
        D: (n, n) distance matrix with a zero diagonal.  Cast to float32
            (the pipeline-wide comparison dtype) before selection.
        k: neighborhood size, ``0 <= k <= n-1``.  k = 0 yields an empty
            graph (shape (n, 0)); callers normally clamp to n-1.

    Returns:
        NeighborGraph with ``indices (n, k)`` / ``distances (n, k)``; the
        self point never appears in its own neighbor list.

    Raises:
        ValueError: if ``k`` exceeds n-1 (there are only n-1 other points).

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1., 4.], [1., 0., 2.], [4., 2., 0.]])
        >>> g = knn_from_distances(D, k=1)
        >>> g.indices.tolist(), g.distances.tolist()
        ([[1], [0], [1]], [[1.0], [1.0], [2.0]])
    """
    D = jnp.asarray(D, jnp.float32)
    n = D.shape[0]
    if k > max(n - 1, 0):
        raise ValueError(f"k={k} exceeds the n-1={n - 1} available neighbors")
    if k <= 0:
        return NeighborGraph(jnp.zeros((n, 0), jnp.int32),
                             jnp.zeros((n, 0), jnp.float32))
    eye = jnp.eye(n, dtype=bool)
    dist, idx = _top_k_rows(jnp.where(eye, -jnp.inf, -D), k)
    return NeighborGraph(idx, dist)


def knn_from_features(
    X: jnp.ndarray,
    k: int,
    *,
    metric: str = "euclidean",
    row_chunk: int | str = 1024,
    impl: str | None = None,
    tile: int | str = "auto",
) -> NeighborGraph:
    """Select k nearest neighbors straight from feature vectors.

    The distance matrix is never materialized.  Since PR 9 this is a thin
    facade over the streaming selection machinery in
    ``kernels.ops.topk_select``: the Pallas streaming kernel
    (``kernels/pald_topk.py``) on TPU, the blocked-jnp fallback (direct or
    tile-min-prefiltered slab top-k) elsewhere — every impl bitwise
    identical to the original slab-``lax.top_k`` contract, stable
    lower-index-first tie-break included.

    Args:
        X: (n, d) feature matrix, any float dtype (cast to float32 once).
        k: neighborhood size, ``0 <= k <= n-1``.
        metric: one of ``features.METRICS`` (sqeuclidean, euclidean,
            cosine, manhattan) — the same tile primitive
            (``features.dist_tile``) the fused kernels use, so distances
            agree with ``cdist_reference`` up to summation order.
        row_chunk: rows per selection slab; bounds peak memory
            (O(row_chunk * n + n * k)), does not change the result.
            ``"auto"`` resolves via the ``pald_topk:k<k>:d<d>`` tuning
            cache pass.
        impl: selection impl override ('pallas'/'interpret'/'jnp'/
            'chunked'); None = backend default.
        tile: tile-min prefilter width (see ``kernels.ops.topk_select``);
            "auto" = tuned, a value >= n disables the prefilter.

    Returns:
        NeighborGraph over the metric's distances.

    Raises:
        ValueError: unknown metric, or ``k > n-1``.

    Example:
        >>> import jax.numpy as jnp
        >>> X = jnp.asarray([[0.0], [1.0], [3.0]])
        >>> knn_from_features(X, k=2).indices.tolist()
        [[1, 2], [0, 2], [1, 0]]
    """
    from repro.kernels.ops import topk_select

    return topk_select(X, k, metric=metric, impl=impl, block=row_chunk,
                       tile=tile)


# ---------------------------------------------------------------------------
# the exact-within-neighborhood tile body (shared by jnp fallback + kernel)
# ---------------------------------------------------------------------------
def knn_values_tile(
    dn: jnp.ndarray,
    g: jnp.ndarray,
    own_wins: jnp.ndarray | None,
    ties=DEFAULT_TIES,
    *,
    k_valid: int | None = None,
) -> jnp.ndarray:
    """Sparse cohesion values for one (b, k) row tile of the knn graph.

    Args:
        dn: (b, k) neighbor distances d(x, nbr_j) for the tile's rows.
        g: (b, k, k) gathered neighbor-to-neighbor distances
            ``g[i, a, b] = d(nbr_a(x_i), nbr_b(x_i))`` with an exactly
            zero diagonal.
        own_wins: (b, k) bool — global index of x > index of nbr_j; the
            index tiebreak for functionals declaring
            ``needs_index_tiebreak`` (None otherwise).
        ties: weight functional (name or instance); the focus/support
            contributions come verbatim from ``core/weights``.
        k_valid: number of REAL neighbor columns when k was padded up to
            a lane quantum (Pallas path).  Padded columns carry +inf pair
            distances but FINITE junk gathered distances (their indices
            point at arbitrary real rows), so they are masked out of both
            the focus count (candidate axis) and the pair weights (pair
            axis) here.  None = all columns real.

    Returns:
        (b, k+1) float32 values: column 0 is z = x (self support), column
        1+j is z = nbr_j.  Un-normalized (no 1/(n-1) factor).

    The whole body is plain broadcast arithmetic over the (b, k, k) cube —
    it traces identically inside ``jit`` (the jnp fallback) and inside a
    Pallas kernel body, which is how the two impls stay bit-faithful to
    each other.  Reductions use explicit ``sum`` (not a matmul) so the
    accumulation order is the same everywhere.
    """
    b, k = dn.shape
    zero = jnp.zeros_like(dn)
    mvalid = None
    if k_valid is not None and k_valid < k:
        mvalid = (jnp.arange(k) < k_valid).astype(jnp.float32)
    # pass 1: restricted focus size per directed pair (x, nbr_j):
    # z = x contributes focus_weight(0, d_yx, d_xy); z = nbr_m the cube term
    fw_self = focus_weight(zero, dn, dn, ties)                     # (b, k)
    fw_nbr = focus_weight(dn[:, None, :], g, dn[:, :, None], ties)  # (b, j, m)
    if mvalid is not None:
        fw_nbr = fw_nbr * mvalid[None, None, :]
    U = fw_self + jnp.sum(fw_nbr, axis=-1, dtype=jnp.float32)
    W = jnp.where(U > 0, 1.0 / jnp.where(U > 0, U, 1.0), 0.0)
    if mvalid is not None:
        W = W * mvalid[None, :]
    # pass 2: support of every candidate z against the same pair set
    wfun = resolve_weight(ties)
    if wfun.share is not None:
        # conserves-mass factoring (core/weights contract): support ==
        # nan-guarded share * focus on the SAME (own, other, pair)
        # triples as pass 1, so reuse the focus cube instead of
        # evaluating a second smooth (b, k, k) cube — two op-heavy cube
        # chains in this single fused body make XLA's merged loop spill
        # registers (~3x), and the reuse is bitwise-free
        # no nan-guard on the product: share(a, b) is nan only when BOTH
        # operands are +inf, and the gathered g is finite by construction
        # (junk values at padded slots, never inf), while the focus cube
        # is already guarded — so the product is always finite here
        sw_nbr = wfun.share(dn[:, None, :], g) * fw_nbr
        sw_self = wfun.share(zero, dn) * fw_self
    else:
        ow = None if own_wins is None else own_wins[:, :, None]
        sw_nbr = support_weight(dn[:, None, :], g, dn[:, :, None], ties, ow)
        sw_self = support_weight(zero, dn, dn, ties, own_wins)
    cv_nbr = jnp.sum(sw_nbr * W[:, :, None], axis=1, dtype=jnp.float32)
    cv_self = jnp.sum(sw_self * W, axis=1, dtype=jnp.float32)
    return jnp.concatenate([cv_self[:, None], cv_nbr], axis=1)


def gather_tile_from_distances(D: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """(b, k, k) neighbor-to-neighbor distances gathered from dense D."""
    return D[idx[:, :, None], idx[:, None, :]]


def gather_tile_from_features(X: jnp.ndarray, idx: jnp.ndarray,
                              metric: str) -> jnp.ndarray:
    """(b, k, k) neighbor-to-neighbor distances recomputed from features.

    The diagonal (a == b: the same neighbor against itself) is forced to
    exactly zero — the matmul formulation of d(x, x) is only zero up to fp
    noise, and the "x is in its own focus" invariant needs it exact."""
    from .features import dist_tile

    Xn = X[idx]                                               # (b, k, d)
    G = jax.vmap(lambda A: dist_tile(A, A, metric))(Xn)       # (b, k, k)
    same = idx[:, :, None] == idx[:, None, :]
    return jnp.where(same, 0.0, G)


# ---------------------------------------------------------------------------
# sparse-result utilities
# ---------------------------------------------------------------------------
def scatter_dense(graph: NeighborGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Expand sparse (n, k+1) cohesion values to the dense (n, n) matrix.

    Args:
        graph: the NeighborGraph the values were computed on.
        values: (n, k+1) from the knn pipeline (column 0 = self).

    Returns:
        (n, n) float32 C with ``C[x, x] = values[x, 0]``,
        ``C[x, graph.indices[x, j]] = values[x, 1+j]`` and exact zeros
        everywhere else (entries the knn restriction never supports).
    """
    n = graph.indices.shape[0]
    rows = jnp.arange(n)
    C = jnp.zeros((n, n), jnp.float32)
    if graph.k:
        C = C.at[rows[:, None], graph.indices].set(values[:, 1:])
    return C.at[rows, rows].set(values[:, 0])


def local_depths(values: jnp.ndarray) -> jnp.ndarray:
    """l_x = sum_z c_xz over the stored entries (all others are exact 0)."""
    return jnp.sum(values, axis=-1)


def universal_threshold(values: np.ndarray) -> float:
    """tau = mean(self-cohesion) / 2 on the sparse value layout.

    The sparse analogue of ``analysis.universal_threshold``: column 0 of
    ``values`` IS the diagonal of C.  Assumes normalized values (the
    default ``normalize=True`` of the public entry points)."""
    return float(np.mean(np.asarray(values)[..., 0])) / 2.0


def strong_ties(graph: NeighborGraph, values: np.ndarray,
                threshold: float | None = None):
    """Symmetrized strong ties on the sparse structure.

    A tie (x, y) is strong when ``min(c_xy, c_yx) >= tau``; a direction
    the knn restriction never stored counts as cohesion 0, so only
    MUTUAL neighbor pairs can be strong — the same conservative
    symmetrization ``analysis.strong_ties`` applies densely.

    Args:
        graph: the NeighborGraph.
        values: (n, k+1) cohesion values.
        threshold: tau override; default ``universal_threshold(values)``.

    Returns:
        (src, dst, weight) numpy arrays of the strong directed edges with
        src < dst (each unordered strong tie reported once).
    """
    idx = np.asarray(graph.indices)
    n, k = idx.shape
    v = np.asarray(values)
    tau = universal_threshold(v) if threshold is None else threshold
    if k == 0:
        z = np.zeros(0)
        return z.astype(np.int64), z.astype(np.int64), z
    src = np.repeat(np.arange(n, dtype=np.int64), k)
    dst = idx.ravel().astype(np.int64)
    w = v[:, 1:].ravel().astype(np.float64)
    key = src * n + dst
    order = np.argsort(key)
    skey = key[order]
    pos = np.searchsorted(skey, dst * n + src)
    pos_c = np.minimum(pos, len(skey) - 1)
    has_rev = skey[pos_c] == dst * n + src
    w_rev = np.where(has_rev, w[order][pos_c], 0.0)
    sym = np.minimum(w, w_rev)
    keep = (sym >= tau) & (src < dst)
    return src[keep], dst[keep], sym[keep]


def communities(graph: NeighborGraph, values: np.ndarray,
                threshold: float | None = None) -> list[list[int]]:
    """Connected components of the sparse strong-tie graph.

    Same output contract as ``analysis.communities``: components sorted
    by size (largest first, ties by smallest member), members ascending.

    Example:
        >>> import jax.numpy as jnp
        >>> D = jnp.asarray([[0., 1., 9., 9.], [1., 0., 9., 9.],
        ...                  [9., 9., 0., 1.], [9., 9., 1., 0.]])
        >>> from repro.kernels.ops import pald_knn
        >>> g, vals = pald_knn(D, k=2, normalize=True)
        >>> communities(g, vals)
        [[0, 1], [2, 3]]
    """
    from .analysis import connected_components

    src, dst, _ = strong_ties(graph, values, threshold)
    return connected_components(graph.indices.shape[0], zip(src, dst))
