"""Post-compile HLO analysis: collective bytes, roofline terms.

``collective_bytes`` parses the optimized HLO text of a compiled executable,
builds a symbol table of instruction result shapes, and sums the *operand*
sizes of every collective op (all-gather, all-reduce, reduce-scatter,
all-to-all, collective-permute), per the roofline methodology.

Hardware constants are TPU v5e-class: 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s per ICI link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = f32[128,256]{1,0} op-name(...operands...)`
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^=]*?\)|[\w\[\],{}: ]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)",
)
_SHAPE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")
_OPERAND = re.compile(r"%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of one (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    # kind -> (count, operand bytes, traffic bytes)
    by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        """Operand bytes (the brief's definition)."""
        return sum(b for _, b, _ in self.by_kind.values())

    @property
    def total_traffic(self) -> int:
        """Modeled per-chip link traffic (what the roofline term uses):
        all-gather receives out−in; all-reduce moves ~2×in (ring
        send+receive); reduce-scatter in−out; permute/all-to-all in."""
        return sum(t for _, _, t in self.by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(c for c, _, _ in self.by_kind.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_traffic": self.total_traffic,
            "total_count": self.total_count,
            "by_kind": {
                k: {"count": c, "bytes": b, "traffic": t}
                for k, (c, b, t) in self.by_kind.items()
            },
        }


def _traffic(kind: str, op_bytes: int, out_bytes: int) -> int:
    if kind == "all-gather":
        return max(out_bytes - op_bytes, 0)
    if kind == "all-reduce":
        return 2 * op_bytes
    if kind == "reduce-scatter":
        return max(op_bytes - out_bytes, 0)
    return op_bytes  # permute, all-to-all


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse operand/traffic bytes of every collective in an HLO dump."""
    sizes: dict[str, int] = {}
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op, args = m.group("name", "type", "op", "args")
        sizes[name] = _shape_bytes(type_str)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # operand bytes: look each %operand up in the symbol table; fall back
        # to the result size when an operand is unknown (entry params).
        ob = 0
        for om in _OPERAND.finditer(args):
            nm = om.group(1)
            if nm in sizes and nm != name:
                ob += sizes[nm]
        if ob == 0:
            ob = sizes[name]
        c, b, t = stats.by_kind.get(kind, (0, 0, 0))
        stats.by_kind[kind] = (c + 1, b + ob, t + _traffic(kind, ob, sizes[name]))
    return stats


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    coll_bytes: float,
    chips: int,
    flops_is_global: bool = True,
) -> dict:
    """The three roofline times (seconds) + the dominant term.

    ``cost_analysis()`` of an SPMD executable reports the per-device
    partitioned program; with ``flops_is_global=False`` the numbers are taken
    as already per-chip and are NOT divided by the chip count.
    """
    div = chips if flops_is_global else 1
    t_comp = hlo_flops / div / PEAK_FLOPS
    t_mem = hlo_bytes / div / HBM_BW
    t_coll = coll_bytes / div / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).removesuffix("_s")
    terms["bound_s"] = max(t_comp, t_mem, t_coll)
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens/step.

    For decode shapes D is the new tokens only (global_batch × 1)."""
    _, active = cfg.param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens
