"""Top-k capacity-based Mixture-of-Experts with expert parallelism.

MaxText-style "dropping" implementation that is pure-pjit friendly: tokens
are grouped (group = tokens that stay on one data shard), each group
dispatches into an (experts, capacity) buffer with one-hot einsums, the
expert FFN runs with the expert dimension sharded over the ``model`` mesh
axis (EP), and a combine einsum scatters results back.  All shapes static;
overflowing tokens beyond ``capacity_factor * k * T / E`` are dropped
(standard at-scale behaviour).

Dispatch/combine einsum FLOPs are ~0.2% of expert FLOPs at the assigned
configs (DESIGN.md), so HLO_FLOPs stays honest w.r.t. 6*N_active*D.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def init_moe(key, d: int, moe_cfg):
    e, ff = moe_cfg.n_experts, moe_cfg.d_ff
    ks = jax.random.split(key, 4)
    params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[2], (e, d, ff), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[3], (e, ff, d), jnp.float32) * ff ** -0.5,
    }
    specs = {
        "router": ("embed_nosplit", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    return params, specs


def moe_apply(
    params,
    x: Array,               # (B, S, d)
    moe_cfg,
    act: str,
    *,
    group_tokens: int | None = None,   # target tokens per dispatch group
    shard_constraints: bool = False,
) -> tuple[Array, Array]:
    """Returns (output (B, S, d), router aux loss scalar).

    Tokens are split into groups of ~``group_tokens`` before dispatch so the
    (g, tg, e, cap) dispatch/combine tensors stay O(k * T * tg) total instead
    of O(k * T^2 / g) — with tg=512 the dispatch einsum FLOPs are ~2% of the
    expert FLOPs at the assigned MoE configs.  The group dim inherits the
    batch sharding under pjit (g is a multiple of the data-shard count
    whenever B is).
    """
    B, S, d = x.shape
    e, k = moe_cfg.n_experts, moe_cfg.top_k
    T = B * S
    tg = min(group_tokens or moe_cfg.group_tokens, T)
    while T % tg:
        tg -= 1
    g = T // tg
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (g, tg, e)
    gate_vals, ids = jax.lax.top_k(probs, k)                      # (g, tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = int(moe_cfg.capacity_factor * k * tg / e)
    cap = max(cap, k)

    # expert-axis sharding helper: GSPMD does not reliably infer that the
    # dispatch/combine chain should shard its `e` dim with the expert-
    # sharded weights, and replicates it over 'model' instead (measured 5x
    # flop inflation / 77 GB all-reduces at phi3.5 train_4k; §Perf 1)
    if shard_constraints:
        from repro.sharding import partition as _part

        def on_e(t, dim):
            return _part.shard_dim(t, dim, "model")
    else:
        def on_e(t, dim):
            return t

    # position of each (token, choice) within its expert's capacity buffer
    onehot = on_e(jax.nn.one_hot(ids, e, dtype=jnp.int32), 3)    # (g, tg, k, e)
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                            # (g, tg*k, e)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, tg, k)          # (g, tg, k)
    keep = pos < cap

    # dispatch[g, t, e, c] in {0,1}; combine carries the gate weight
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None]
    disp = on_e(jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), pos_oh), 2)
    comb = on_e(jnp.einsum(
        "gtke,gtkc->gtec", onehot.astype(jnp.float32), pos_oh.astype(jnp.float32) * gate_vals[..., None]
    ).astype(x.dtype), 2)

    xe = on_e(jnp.einsum("gtec,gtd->gecd", disp, xt), 1)          # (g, e, cap, d)
    a = jax.nn.silu if act == "silu" else (lambda t: jax.nn.gelu(t, approximate=True))
    h = a(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, params["w_up"]
    )
    ye = on_e(jnp.einsum("gecf,efd->gecd", on_e(h, 1), params["w_down"]), 1)
    y = jnp.einsum("gecd,gtec->gtd", ye, comb).reshape(B, S, d)

    # load-balance aux loss (Switch-style)
    frac_tokens = jnp.mean(onehot.astype(jnp.float32), axis=(1, 2))  # (g, e)
    frac_probs = jnp.mean(probs, axis=1)                              # (g, e)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return y, aux * moe_cfg.router_aux_coef
