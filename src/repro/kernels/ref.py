"""Pure-jnp oracles for the PaLD Pallas kernels.

Kept deliberately naive (one O(n^3) broadcast, z-chunked) so kernel tests
compare against straight-line jnp semantics, independent of the blocked
implementations in repro.core.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.weights import (DEFAULT_TIES, focus_weight, resolve_weight,
                                support_weight)

__all__ = ["focus_ref", "cohesion_ref", "weights_ref"]


def focus_ref(D: jnp.ndarray, *, ties=DEFAULT_TIES) -> jnp.ndarray:
    D = D.astype(jnp.float32)
    m = focus_weight(D[:, None, :], D[None, :, :], D[:, :, None], ties)
    return jnp.sum(m, axis=-1).astype(jnp.float32)


def weights_ref(U: jnp.ndarray, n_valid=None) -> jnp.ndarray:
    n = U.shape[0]
    eye = jnp.eye(n, dtype=bool)
    W = jnp.where(eye | (U == 0), 0.0, 1.0 / jnp.where(U == 0, 1.0, U))
    if n_valid is not None:
        valid = jnp.arange(n) < n_valid
        W = W * valid[:, None] * valid[None, :]
    return W.astype(jnp.float32)


def cohesion_ref(D: jnp.ndarray, W: jnp.ndarray, *,
                 ties=DEFAULT_TIES) -> jnp.ndarray:
    ties = resolve_weight(ties)
    D = D.astype(jnp.float32)
    n = D.shape[0]
    ids = jnp.arange(n)
    xw = ((ids[:, None] > ids[None, :])[:, :, None]
          if ties.needs_index_tiebreak else None)
    # g[x, y, z] = support_weight(d_xz, d_yz, d_xy)
    g = support_weight(D[:, None, :], D[None, :, :], D[:, :, None], ties, xw)
    return jnp.einsum("xyz,xy->xz", g, W.astype(jnp.float32))
