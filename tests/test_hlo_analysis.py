"""Collective-bytes parser + roofline terms."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import shard_map_compat
from repro.launch import hlo_analysis as H
from repro.launch import mesh as meshlib


def test_shape_bytes():
    assert H._shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H._shape_bytes("bf16[8]") == 16
    assert H._shape_bytes("(f32[2,2]{1,0}, s32[4])") == 16 + 16
    assert H._shape_bytes("pred[]") == 1  # scalar = 1 element


def test_parser_on_synthetic_hlo():
    txt = """
  %x = f32[16,64]{1,0} parameter(0)
  %ag = f32[128,64]{1,0} all-gather(f32[16,64]{1,0} %x), replica_groups={}
  %ar = f32[128,64]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%ar), dimensions={0}
  ROOT %out = f32[16,64]{1,0} copy(%rs)
"""
    stats = H.collective_stats(txt)
    assert stats.by_kind["all-gather"][0] == 1
    assert stats.by_kind["all-gather"][1] == 16 * 64 * 4      # operand size
    assert stats.by_kind["all-reduce"][1] == 128 * 64 * 4
    assert stats.by_kind["reduce-scatter"][1] == 128 * 64 * 4
    assert stats.total_count == 3


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_parser_on_real_compiled_module():
    """psum of a (8, 32) array over 8 devices => one all-reduce whose operand
    bytes we can predict exactly."""
    mesh = meshlib.make_test_mesh((8,), ("data",))

    def f(x):
        return jax.lax.psum(x, "data")

    sharded = shard_map_compat(f, mesh=mesh, in_specs=P("data"), out_specs=P())
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32,
                             sharding=NamedSharding(mesh, P("data")))
    compiled = jax.jit(sharded).lower(x).compile()
    stats = H.collective_stats(compiled.as_text())
    assert stats.by_kind.get("all-reduce", (0, 0))[0] >= 1
    # per-device operand is the local (1, 32) f32 shard
    assert stats.by_kind["all-reduce"][1] == 32 * 4


def test_fused_path_never_materializes_D():
    """ISSUE 2 acceptance: the fused features→cohesion pipeline must never
    hold the full (n, n) distance matrix.

    Verified on the compiled executables' memory analysis: pass 1 of the
    fused path peaks *below the size of one D buffer* (n^2 f32), so a full
    distance matrix cannot exist at any point in it, while the materialized
    counterpart of the same computation carries at least D itself.  The
    full fused pipeline legitimately holds U and W (both (n, n)) — the
    assertion there is relative: at least one n^2 buffer less than
    materialize-then-kernel, at identical block sizes.
    """
    from repro.core import features
    from repro.kernels import ops

    n, d, blk = 512, 8, 16
    X = jnp.zeros((n, d), jnp.float32)
    d_bytes = n * n * 4

    def temp(fn):
        return jax.jit(fn).lower(X).compile().memory_analysis().temp_size_in_bytes

    fused_focus = temp(lambda X: ops._focus_fused_jnp(
        X, metric="sqeuclidean", block=blk, block_z=blk, n_valid=n))
    mat_focus = temp(lambda X: ops._focus_general_jnp(
        *(features.cdist_reference(X, metric="sqeuclidean"),) * 3, chunk=blk))
    assert fused_focus < d_bytes, (
        f"fused focus peaks at {fused_focus} B >= one D ({d_bytes} B): "
        "a full distance matrix fits in its temps")
    assert mat_focus >= d_bytes  # sanity: the materialized path does hold D

    fused_pipe = temp(lambda X: ops.pald_fused(
        X, metric="sqeuclidean", block=blk, block_z=blk, impl="jnp"))
    mat_pipe = temp(lambda X: ops.pald(
        features.cdist_reference(X, metric="sqeuclidean"),
        block=blk, block_z=blk, impl="jnp"))
    assert fused_pipe + d_bytes <= mat_pipe, (
        f"fused pipeline ({fused_pipe} B) saves less than one D buffer vs "
        f"materialized ({mat_pipe} B)")


def test_fused_select_cohere_never_materializes_D():
    """ISSUE 9 acceptance: the fused select->cohere pipeline allocates
    neither the (n, n) distance matrix nor a full per-row scored vector
    beyond one (chunk, n) slab.

    The jnp fused program is one lax.map over row slabs: selection, the
    neighbor feature gather and the cohesion tile body share each step,
    so its compiled temps must stay under ONE (n, n) f32 buffer and under
    a small multiple of the (chunk, n) slab — the working set the module
    comment in kernels/ops.py promises."""
    from repro.kernels import ops

    n, d, k, chunk = 2048, 8, 16, 128
    X = jnp.zeros((n, d), jnp.float32)
    d_bytes = n * n * 4
    slab_bytes = chunk * n * 4

    def temp(fn):
        return (jax.jit(fn).lower(X).compile()
                .memory_analysis().temp_size_in_bytes)

    fused = temp(lambda X: ops.select_cohere(
        X, k=k, block=chunk, tile=n)[1])
    assert fused < d_bytes, (
        f"fused select->cohere peaks at {fused} B >= one D ({d_bytes} B): "
        "a full distance matrix fits in its temps")
    assert fused <= 8 * slab_bytes, (
        f"fused select->cohere peaks at {fused} B > 8 slabs "
        f"({8 * slab_bytes} B): per-row state is not O(chunk * n)")

    # the tile-min prefilter strategy obeys the same bound
    pre = temp(lambda X: ops.select_cohere(
        X, k=k, block=chunk, tile=64)[1])
    assert pre < d_bytes and pre <= 8 * slab_bytes

    # selection alone too (the standalone knn_from_features backend)
    sel = temp(lambda X: (g := ops.topk_select(
        X, k, impl="jnp", block=chunk, tile=n)).distances)
    assert sel < d_bytes and sel <= 8 * slab_bytes


def test_roofline_terms():
    t = H.roofline_terms(hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=50e9,
                         chips=1, flops_is_global=False)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t2 = H.roofline_terms(hlo_flops=1e15, hlo_bytes=1e9, coll_bytes=0,
                          chips=1, flops_is_global=False)
    assert t2["bottleneck"] == "compute"


def test_model_flops():
    from repro import configs
    from repro.configs.base import SHAPES
    cfg = configs.get("llama3.2-3b")
    mf_train = H.model_flops(cfg, SHAPES["train_4k"])
    _, active = cfg.param_count()
    assert mf_train == pytest.approx(6 * active * 4096 * 256)
    mf_dec = H.model_flops(cfg, SHAPES["decode_32k"])
    assert mf_dec == pytest.approx(2 * active * 128)
    # MoE uses active (not total) params
    moe = configs.get("phi3.5-moe-42b-a6.6b")
    t, a = moe.param_count()
    assert H.model_flops(moe, SHAPES["train_4k"]) < 6 * t * 4096 * 256 / 3
