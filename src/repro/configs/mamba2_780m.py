"""mamba2-780m — 48L d_model=1536 attn-free (SSD) vocab=50280 ssm_state=128.
[arXiv:2405.21060; unverified]
"""
from .base import LayerSpec, MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    n_layers=48,
    d_model=1536,
    n_heads=24,       # unused (attn-free); kept for interface completeness
    n_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(mixer="mamba", ffn="none"),),
    mamba=MambaConfig(d_state=128, head_dim=64, n_groups=1, conv_width=4,
                      chunk=256, expand=2),
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=2,
    subquadratic=True,  # SSM: O(1) decode state -> long_500k runs
)
