"""Checkpointer behaviour: atomicity, pruning, async, corrupted dirs."""
import json
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ck


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.int32), "c": [jnp.zeros(()), jnp.ones(())]},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    path = ck.save(str(tmp_path), 3, t)
    assert path.endswith("step_00000003")
    template = jax.eval_shape(lambda: t)
    r = ck.restore(path, template)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_ignores_incomplete(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, t)
    # a crash mid-save leaves a .tmp dir — must be ignored
    os.makedirs(tmp_path / "step_00000005.tmp")
    # a dir without manifest (partial rename) must be ignored too
    os.makedirs(tmp_path / "step_00000004")
    template = jax.eval_shape(lambda: t)
    _, step = ck.restore_latest(str(tmp_path), template)
    assert step == 2


def test_restore_empty_dir(tmp_path):
    r, step = ck.restore_latest(str(tmp_path), jax.eval_shape(_tree))
    assert r is None and step == -1


def test_prune_keeps_latest(tmp_path):
    t = _tree()
    for s in range(6):
        ck.save(str(tmp_path), s, t)
    ck.prune(str(tmp_path), keep=2)
    assert ck.available_steps(str(tmp_path)) == [4, 5]


def test_save_overwrites_same_step(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    ck.save(str(tmp_path), 1, t2)
    r = ck.restore(os.path.join(str(tmp_path), "step_00000001"),
                   jax.eval_shape(lambda: t))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t2["a"]))


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(4):
        ac.save(s, jax.tree.map(lambda x: x + s, t))
    ac.wait()
    steps = ck.available_steps(str(tmp_path))
    assert steps == [2, 3]
    r = ck.restore(os.path.join(str(tmp_path), "step_00000003"),
                   jax.eval_shape(lambda: t))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]) + 3)


def test_manifest_contents(tmp_path):
    path = ck.save(str(tmp_path), 0, _tree())
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 0
    assert "a" in man["leaves"]
    assert man["leaves"]["a"]["shape"] == [2, 3]
