"""Batched serving example: prefill + sampled decode on any assigned arch.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m --gen 64

Uses the reduced (CPU-sized) config by default; pass --full on a TPU pod.
"""
import argparse

from repro.launch import serve as serve_cli


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]
    if not args.full:
        argv.append("--smoke")
    serve_cli.main(argv)


if __name__ == "__main__":
    main()
