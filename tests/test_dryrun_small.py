"""Miniature dry-run: every (arch x shape-kind) lowers + compiles on the
8-device test mesh with reduced configs — fast regression guard for the
512-chip production dry-run."""
import dataclasses

import pytest

import jax

from repro import configs
from repro.configs.base import SHAPES, ShapeConfig, reduced, runnable
from repro.launch import mesh as meshlib, specs

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)

SMALL_SHAPES = {
    "train": ShapeConfig("t", 64, 8, "train"),
    "prefill": ShapeConfig("p", 64, 8, "prefill"),
    "decode": ShapeConfig("d", 64, 8, "decode"),
}


@pytest.fixture(scope="module")
def mesh():
    return meshlib.make_test_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cell_compiles(arch, kind, mesh):
    cfg = reduced(configs.get(arch))
    shape = SMALL_SHAPES[kind]
    fn, args = specs.cell_lowerable(cfg, shape, mesh, q_chunk=32)
    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_probe_unroll_variant_compiles(mesh):
    """The dry-run cost probes (scan + q-chunk unrolled) compile too."""
    cfg = dataclasses.replace(
        reduced(configs.get("gemma2-2b")), scan_unroll=2, probe_unroll=True,
        n_layers=4,
    )
    fn, args = specs.cell_lowerable(cfg, SMALL_SHAPES["train"], mesh, q_chunk=32)
    with mesh:
        jax.jit(fn).lower(*args).compile()


def test_full_config_lowers_on_test_mesh(mesh):
    """One FULL (non-reduced) config must at least lower abstractly on the
    small mesh (no allocation happens)."""
    cfg = configs.get("internvl2-1b")
    shape = ShapeConfig("t", 256, 8, "train")
    fn, args = specs.cell_lowerable(cfg, shape, mesh, q_chunk=128)
    with mesh:
        jax.jit(fn).lower(*args)


def test_runnable_skips_long_context():
    cfg = configs.get("qwen2.5-14b")
    ok, why = runnable(cfg, SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = runnable(configs.get("mamba2-780m"), SHAPES["long_500k"])
    assert ok
