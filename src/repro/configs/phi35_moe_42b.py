"""phi3.5-moe-42b-a6.6b — 32L d_model=4096 32H (GQA kv=8) d_ff=6400(per-expert)
vocab=32064, MoE 16 experts top-2 every layer.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,  # all-FFN capacity lives in the experts
    vocab_size=32064,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400),
    rope_theta=10000.0,
    sharding_profile="zero3",   # 42B total params: shard everything
    remat="full",
    train_microbatches=4,
    subquadratic=False,
)
