"""Core transformer layers: RMSNorm, RoPE, GQA attention, gated MLP.

Functional style: ``init_*`` returns ``(params, specs)`` where ``specs``
mirrors the param tree with *logical* axis-name tuples per dimension
(mapped to mesh axes by ``repro.sharding.partition``).  ``apply`` functions
are pure.

Attention is computed with fp32 softmax and **query chunking** (a scan over
query blocks) so peak score memory is O(q_chunk * kv_len) instead of
O(seq^2) — required for the 32k prefill shapes to fit v5e HBM.  Sliding
windows, GQA, attention-logit softcapping (gemma2) and QKV bias (qwen2.5)
are all supported.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# norms / embeddings / rope
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed_nosplit",)}


def rmsnorm(params, x: Array, eps: float, f32: bool = True) -> Array:
    if f32:
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
        return y.astype(x.dtype)
    # bf16 normalize, f32 statistics: avoids materializing a full f32 copy
    # of the residual at the layer boundary — which XLA otherwise hoists
    # into the scan stash, doubling (bf16 + f32 = 3x) the saved bytes per
    # layer (§Perf 1.3)
    var = (jnp.einsum("...d,...d->...", x, x,
                      preferred_element_type=jnp.float32) / x.shape[-1])
    r = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * r * params["scale"].astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    emb = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"embedding": emb}, {"embedding": ("vocab", "embed")}


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim), positions: (seq,) or
    broadcastable to x's batch/seq dims."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg):
    """Attention parameters in explicit 3-D head layout.

    Keeping the head axis as a real tensor dimension (instead of a flat
    h*hd matrix) is what makes GQA tensor parallelism expressible in GSPMD:
    the 'q_heads' / 'kv_heads' logical axes shard over 'model' only when the
    head count divides it (see repro.sharding.partition).  KV heads usually
    don't (GQA kv=8 < model=16) and stay replicated — Megatron-style GQA TP.
    """
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    params = {
        "wq": jax.random.normal(ks[0], (d, h, hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, kv, hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, kv, hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), jnp.float32) * s,
    }
    specs = {
        "wq": ("embed", "q_heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("q_heads", None, "embed"),
    }
    if cfg.qkv_bias:
        params |= {
            "bq": jnp.zeros((h, hd), jnp.float32),
            "bk": jnp.zeros((kv, hd), jnp.float32),
            "bv": jnp.zeros((kv, hd), jnp.float32),
        }
        specs |= {
            "bq": ("q_heads", None),
            "bk": ("kv_heads", None),
            "bv": ("kv_heads", None),
        }
    return params, specs


def _attend(
    q: Array,          # (B, Sq, H, hd)  flat query heads
    k: Array,          # (B, Skv, KV, hd)
    v: Array,          # (B, Skv, KV, hd)
    q_positions: Array,   # (Sq,) global token positions of queries
    kv_positions: Array,  # (Skv,) global token positions of kv slots (-1 invalid)
    window: Optional[int],
    softcap: Optional[float],
    out_f32: bool = True,
) -> Array:
    """Masked softmax attention for one query block.

    The query head axis stays FLAT (H, not (KV, G)) so a 'model'-axis shard
    of q-heads remains expressible; K/V are broadcast to H inside the
    einsums (jnp.repeat of a replicated operand — XLA fuses it).  QK/PV
    einsums run in the input dtype with fp32 accumulation; softmax is fp32;
    probs are cast back to the input dtype so the largest intermediate
    (scores) exists once in fp32 and once in bf16, not twice in fp32.
    """
    H, KV = q.shape[2], k.shape[2]
    g = H // KV
    if g > 1:
        k = jnp.repeat(k, g, axis=2)   # (B, Skv, H, hd)
        v = jnp.repeat(v, g, axis=2)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum(
        "bqhe,bshe->bhqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = kv_positions[None, :] <= q_positions[:, None]      # causal
    mask &= kv_positions[None, :] >= 0                        # validity
    if window is not None:
        mask &= kv_positions[None, :] > q_positions[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if out_f32:
        out = jnp.einsum(
            "bhqs,bshe->bqhe", probs, v, preferred_element_type=jnp.float32
        )
    else:
        out = jnp.einsum("bhqs,bshe->bqhe", probs, v)
    return out


def attention_apply(
    params,
    cfg,
    x: Array,                      # (B, S, d)
    *,
    positions: Array,              # (S,) global positions of x tokens
    window: Optional[int],
    kv_cache: Optional[dict] = None,   # {"k","v"}: (B, Sc, KV, hd), "pos": scalar
    q_chunk: int = 512,
    unroll: bool = False,              # python-loop the q chunks (cost probes)
) -> tuple[Array, Optional[dict]]:
    """Returns (output (B, S, d), updated kv_cache or None).

    Without a cache: causal self-attention over x (train / one-shot scoring).
    With a cache: entries of x are written at ``positions`` into the
    (possibly windowed, circular) cache, then attend over the whole cache —
    used for both prefill (S = prompt length) and decode (S = 1).
    """
    B, S, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    from repro.sharding import partition as _part
    use_seq_tp = h % _part.model_axis_size() != 0
    if use_seq_tp and cfg.attn_seq_proj:
        # §Perf 2 (Megatron-SP analogue): when heads can't shard, split the
        # PROJECTION compute by sequence too — weights are replicated over
        # 'model', but each chip projects only its sequence slice
        x = _part.seq_shard(x, dim=1)
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])    # (B, S, H, hd)
    kx = jnp.einsum("bsd,dke->bske", x, params["wk"])   # (B, S, KV, hd)
    vx = jnp.einsum("bsd,dke->bske", x, params["wv"])
    if cfg.qkv_bias:
        q, kx, vx = q + params["bq"], kx + params["bk"], vx + params["bv"]
    q = rope(q, positions, cfg.rope_theta)
    kx = rope(kx, positions, cfg.rope_theta)

    # context parallelism: when q-heads don't divide the 'model' axis (GQA
    # head counts often don't), shard the query SEQUENCE dim over 'model'
    # instead — attention compute splits by q blocks, K/V are gathered once
    if use_seq_tp:
        q = _part.seq_shard(q, dim=1)

    softcap = cfg.attn_logit_softcap
    new_cache = None
    if kv_cache is None:
        k_all, v_all = kx, vx
        kv_positions = positions
    else:
        Sc = kv_cache["k"].shape[1]
        # circular write for windowed caches; identity for full caches
        slots = positions % Sc
        cdt = kv_cache["k"].dtype
        # positions are batch-uniform, so cache writes use
        # dynamic-update-slice wherever the written span is contiguous —
        # DUS partitions as a masked select under GSPMD, whereas the
        # batched scatter triggers "involuntary full rematerialization"
        # (replicate + repartition) on seq-sharded caches.
        if S <= Sc:
            # decode (S=1) and fresh prefill: span [slots[0], slots[0]+S)
            # is contiguous (a prefill that wrapped the circular window
            # would not be, but serving always prefills a fresh cache)
            k_all = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], kx.astype(cdt), slots[0], axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], vx.astype(cdt), slots[0], axis=1)
        else:
            # prompt longer than the sliding window: only the last Sc
            # tokens survive; their slots tile the cache exactly once
            kl, vl, sl = kx[:, -Sc:], vx[:, -Sc:], slots[-Sc:]
            k_all = jnp.zeros_like(kv_cache["k"]).at[:, sl].set(kl.astype(cdt))
            v_all = jnp.zeros_like(kv_cache["v"]).at[:, sl].set(vl.astype(cdt))
        cpos = kv_cache["pos"]  # first position being written this call
        last = cpos + S - 1     # last global position now present
        slot_ids = jnp.arange(Sc)
        # token held by slot s = largest t <= last with t % Sc == s
        tok = last - ((last - slot_ids) % Sc)
        kv_positions = jnp.where(tok >= 0, tok, -1)
        new_cache = {"k": k_all, "v": v_all, "pos": cpos + S}

    # rematerialize scores in the backward pass: without this, scanning over
    # q chunks stacks every chunk's (bx, S_kv) score block as a saved
    # residual — measured 7 GiB/chip at train_4k before the checkpoint
    def q_block(qc, qpos):
        return _attend(qc, k_all, v_all, qpos, kv_positions, window, softcap,
                       cfg.attn_out_f32)

    if S > q_chunk and S % q_chunk == 0:
        nc = S // q_chunk
        qs = q.reshape(B, nc, q_chunk, h, hd)
        ps = positions.reshape(nc, q_chunk)
        if unroll:
            # identical math, loop unrolled so XLA cost analysis counts
            # every chunk (while bodies are counted once)
            outs = [q_block(qs[:, i], ps[i]) for i in range(nc)]
            out = jnp.stack(outs, axis=1).reshape(B, S, h, hd)
        else:
            out = jax.lax.map(
                jax.checkpoint(lambda args: q_block(args[0], args[1])),
                (jnp.moveaxis(qs, 1, 0), ps),
            )  # (nc, B, q_chunk, H, hd)
            out = jnp.moveaxis(out, 0, 1).reshape(B, S, h, hd)
    else:
        out = q_block(q, positions)

    out = out.astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, ff: int):
    ks = jax.random.split(key, 3)
    params = {
        "w_gate": jax.random.normal(ks[0], (d, ff), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[1], (d, ff), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (ff, d), jnp.float32) * ff ** -0.5,
    }
    specs = {
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }
    return params, specs


def mlp_apply(params, x: Array, act: str) -> Array:
    a = jax.nn.silu if act == "silu" else (lambda t: jax.nn.gelu(t, approximate=True))
    return (a(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
