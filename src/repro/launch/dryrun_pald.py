import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run of the paper's own workload: distributed PaLD.

Lowers + compiles ``pald_distributed`` for n up to 10^5 points on the
single-pod (16,16) and multi-pod (2,16,16) meshes, per strategy, and
derives the roofline terms.  PaLD ops are comparisons+FMAs on the VPU, not
MXU matmuls, so the compute term uses the VPU-op peak; the collective term
is where the strategies differ (this is the paper's scalability story at
pod scale).

    python -m repro.launch.dryrun_pald --n 100000 --mesh both
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed, engine
from repro.core.distributed import shard_map_compat
from repro.launch import hlo_analysis, mesh as meshlib

# v5e VPU: 8 lanes x 128 sublanes x 4 ALUs x ~0.94 GHz ~= 3.85e12 op/s fp32.
VPU_PEAK = 3.85e12


def pald_ops(n: int) -> float:
    """Branch-free dense-pairwise op count (cmp+select+fma), DESIGN.md §7:
    pass1 2 cmp + 1 or + 1 add = 4, pass2 2 cmp + 1 and + 2 fma = 5 per
    (pair, z) -> ~9 n^3 ops over the full cube (we do n^3, not n^3/2,
    in the regular dense form)."""
    return 9.0 * n ** 3


def run_cell(n: int, multi_pod: bool, strategy: str, *, dtype=jnp.float32,
             verbose=True) -> dict:
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cell = {"workload": f"pald-n{n}", "strategy": strategy,
            "dtype": jnp.dtype(dtype).name,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}

    axis_names = list(mesh.axis_names)
    row_axes = tuple(a for a in axis_names if a != axis_names[-1])
    col_axis = axis_names[-1]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    if strategy in ("allgather", "ring"):
        spec_in = P(tuple(axis_names), None)
        lp = engine.plan_local(max(n // chips, 1), impl="jnp")
        body = functools.partial(
            distributed._allgather_body if strategy == "allgather"
            else distributed._ring_body,
            axis=tuple(axis_names), n_valid=None, plan=lp,
            **({"p": chips} if strategy == "ring" else {}),
        )
        out_spec = spec_in
    else:
        spec_in = P(row_axes, col_axis)
        pr = 1
        for a in row_axes:
            pr *= sizes[a]
        lp = engine.plan_local(max(n // pr, 1), impl="jnp")
        body = functools.partial(
            distributed._2d_body, row_axes=row_axes, col_axis=col_axis,
            stream_axis="pod" if (strategy == "2d+stream" and multi_pod) else None,
            n_valid=None, mesh_shape=sizes, plan=lp,
        )
        out_spec = spec_in

    fn = jax.jit(shard_map_compat(
        body, mesh=mesh, in_specs=spec_in, out_specs=out_spec
    ))
    D = jax.ShapeDtypeStruct((n, n), dtype,
                             sharding=NamedSharding(mesh, spec_in))
    t0 = time.time()
    lowered = fn.lower(D)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict], newer dict
        cost = cost[0] if cost else {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()

    # the ring / 2d z-stream loops are fori_loops: bodies counted once.
    # scale the under-counted flops/bytes by the trip count
    trips = 1
    if strategy == "ring":
        trips = chips
    elif strategy == "2d+stream" and multi_pod:
        trips = sizes["pod"]
    flops = float(cost.get("flops", 0.0)) * trips
    byts = float(cost.get("bytes accessed", 0.0)) * trips
    collb = float(coll.total_traffic) * trips

    t_comp = pald_ops(n) / chips / VPU_PEAK
    terms = {
        "compute_s": t_comp,
        "memory_s": byts / hlo_analysis.HBM_BW,
        "collective_s": collb / hlo_analysis.ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    ).removesuffix("_s")
    cell.update(
        status="ok",
        compile_s=round(t_compile, 2),
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        coll_bytes_per_chip=collb,
        pald_ops_per_chip=pald_ops(n) / chips,
        memory_analysis={
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "temp_size_in_bytes")
            if mem is not None and getattr(mem, k, None) is not None
        },
        roofline=terms,
        collectives=coll.as_dict(),
    )
    if verbose:
        ma = cell["memory_analysis"]
        tot = (ma.get("temp_size_in_bytes", 0) + ma.get("argument_size_in_bytes", 0)) / 2**30
        print(f"  ok compile {t_compile:5.1f}s  bytes/dev {tot:6.2f} GiB  "
              f"coll {collb/2**20:,.0f} MiB  compute {t_comp*1e3:.1f} ms  "
              f"coll_t {terms['collective_s']*1e3:.1f} ms  "
              f"bottleneck {terms['bottleneck']}")
    return cell


def knn_pald_ops(n: int, k: int) -> float:
    """Sharded-knn op count: selection scores every (row, candidate, dim)
    triple (~3 ops: diff, fma, compare-amortized) and the sparse cohesion
    runs the same 9-op inner loop as the dense form but over (k+1)-cliques
    only — O(n·k²) instead of O(n³)."""
    return 3.0 * n * n + 9.0 * n * (k + 1) ** 2


def knn_shard_estimate(n: int, d: int, k: int, *, strategy: str,
                       pr: int, pc: int, dtype_bytes: int = 4) -> dict:
    """Cost model for one mesh-sharded knn plan cell (no compile needed).

    Communication comes straight from ``distributed_knn.comm_estimate`` —
    every strategy moves O(n·d) feature words per device-round, never the
    O(n²) distance matrix.  Compute splits into the selection term
    (n²·d/p distance ops) and the sparse cohesion term (n·k²/p), both on
    the VPU.  Importable by tests: ``test_distributed.py`` asserts the
    comm term here matches the distributed_knn docstring's n·d claim.
    """
    from repro.core import distributed_knn as dknn

    p = pr * pc
    comm = dknn.comm_estimate(strategy, n=n, d=d, k=k, p=p, pr=pr, pc=pc)
    sel_ops = 3.0 * n * n * d / p
    coh_ops = 9.0 * n * (k + 1) ** 2 / p
    coll_bytes = comm["per_device_words"] * dtype_bytes
    terms = {
        "compute_s": (sel_ops + coh_ops) / VPU_PEAK,
        "collective_s": coll_bytes / hlo_analysis.ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "collective_s"), key=lambda kk: terms[kk]
    ).removesuffix("_s")
    return {
        "workload": f"pald-knn-n{n}-k{k}", "strategy": comm["strategy"],
        "mesh": f"{pr}x{pc}", "chips": p, "status": "ok",
        "selection_ops_per_chip": sel_ops,
        "cohesion_ops_per_chip": coh_ops,
        "comm": comm,
        "coll_bytes_per_chip": coll_bytes,
        "roofline": terms,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=102400)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--strategies", default="allgather,ring,2d,2d+stream")
    ap.add_argument("--dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--out", default="benchmarks/dryrun_out_pald")
    ap.add_argument("--knn-k", type=int, default=None,
                    help="emit mesh-sharded knn plan estimates for this k "
                         "instead of compiling the dense bodies")
    ap.add_argument("--knn-d", type=int, default=64,
                    help="feature dim for the knn estimates")
    args = ap.parse_args()
    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    if args.knn_k is not None:
        for multi in meshes:
            pr, pc = (32, 16) if multi else (16, 16)
            for strat in args.strategies.split(","):
                if strat == "2d+stream":
                    continue
                tag = (f"paldknn{args.n}k{args.knn_k}__{strat}"
                       f"__{'multi' if multi else 'single'}")
                print(f"[dryrun-pald] {tag}")
                cell = knn_shard_estimate(
                    args.n, args.knn_d, args.knn_k, strategy=strat,
                    pr=pr, pc=pc)
                t = cell["roofline"]
                print(f"  est compute {t['compute_s']*1e3:.2f} ms  "
                      f"coll {cell['coll_bytes_per_chip']/2**20:,.1f} MiB  "
                      f"coll_t {t['collective_s']*1e3:.2f} ms  "
                      f"bottleneck {t['bottleneck']}")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(cell, f, indent=1)
        print("[dryrun-pald] done, 0 failures")
        raise SystemExit(0)
    for multi in meshes:
        for strat in args.strategies.split(","):
            if strat == "2d+stream" and not multi:
                continue
            tag = (f"pald{args.n}__{strat}__{'multi' if multi else 'single'}"
                   + ("__bf16" if args.dtype == "bfloat16" else ""))
            print(f"[dryrun-pald] {tag}")
            try:
                cell = run_cell(args.n, multi, strat, dtype=dtype)
            except Exception:
                failures += 1
                cell = {"workload": tag, "status": "error",
                        "traceback": traceback.format_exc(limit=12)}
                print(cell["traceback"])
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(cell, f, indent=1)
    print(f"[dryrun-pald] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
