"""Public PaLD API.

    from repro.core import pald
    C = pald.cohesion(D)                      # auto method selection
    C = pald.cohesion(D, method="pairwise")   # blocked pairwise (Fig. 5)
    C = pald.cohesion(D, method="triplet")    # block-symmetric (Alg. 2 analogue)
    C = pald.cohesion(D, method="kernel")     # Pallas TPU kernels (dense grid)
    C = pald.cohesion(D, method="kernel",
                      schedule="tri")         # upper-tri kernel pipeline
    C = pald.cohesion(D, method="dense")      # un-blocked vectorized baseline
    C = pald.from_features(X, metric="cosine")  # fused, from feature vectors

Inputs of any size are padded internally to a block multiple with +inf
distances; padded points land outside every local focus and contribute
nothing, so the result restricted to the original n x n is exact.

``method="auto"`` consults the persistent tuning cache (measured crossovers
recorded by ``benchmarks/hillclimb.py methods``) and falls back to the seed
heuristic on a cold cache.  ``block="auto"`` resolves the tile through the
same cache (``repro.tuning``).

Dtype contract: every entry point casts its input to float32 exactly once,
here at the API boundary (float64 inputs are downcast explicitly — PaLD
depends only on the order of distances, which f32 preserves away from ulp
collisions) and always returns float32.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from repro.tuning import autotune as _tuner

from . import pairwise as _pairwise
from . import triplet as _triplet
from .ties import DEFAULT_TIES, TIE_MODES, validate_ties  # noqa: F401

Method = Literal["auto", "dense", "pairwise", "triplet", "kernel"]
Ties = Literal["drop", "split", "ignore"]

__all__ = ["cohesion", "from_features", "local_depths", "pad_distance_matrix"]


def pad_distance_matrix(
    D: jnp.ndarray, block: int, *, dtype=jnp.float32
) -> tuple[jnp.ndarray, int]:
    """Pad D to a multiple of ``block`` with +inf off-diagonal, 0 diagonal.

    Padded points are infinitely far from everything: they never enter a real
    pair's local focus (inf < d is false) and every real z is inside a padded
    pair's focus but contributes to padded rows of C only.

    The input is cast to ``dtype`` (float32 by default) *here*, before any
    blocked arithmetic — this is the pipeline's one explicit downcast point;
    nothing downstream changes precision again.
    """
    D = jnp.asarray(D, dtype)
    n = D.shape[0]
    m = -(-n // block) * block
    if m == n:
        return D, n
    P = jnp.full((m, m), jnp.inf, D.dtype)
    P = P.at[:n, :n].set(D)
    P = P.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    return P, n


def cohesion(
    D: jnp.ndarray,
    *,
    method: Method = "auto",
    block: int | str = 128,
    block_z: int | str | None = None,
    schedule: str = "dense",
    normalize: bool = True,
    z_chunk: int | None = None,
    ties: Ties = DEFAULT_TIES,
) -> jnp.ndarray:
    """Compute the PaLD cohesion matrix C from a distance matrix D.

    Methods: "dense" (un-blocked vectorized), "pairwise" (blocked Fig. 5),
    "triplet" (block-symmetric), "kernel" (Pallas pipeline; with
    ``schedule="tri"`` both passes run the upper-triangular block schedule
    — half the block-pair visits), or "auto" (measured crossover).  Feature
    input (no D yet) goes through ``pald.from_features`` instead, whose
    fused method never materializes D at all.
    ``block="auto"`` resolves tiles via the tuning cache.

    ``ties`` fixes what an exact distance tie means — the SAME answer on
    every method/schedule/impl (DESIGN.md §9):
      'drop'  (default) a tied z supports neither point of the pair; strict
              comparisons everywhere (the paper's "ignore equality" applied
              branch-free) — cheapest, and exact on tie-free input;
      'split' a tie splits support 0.5/0.5 and a z exactly on the focus
              boundary joins with weight 0.5 (the theoretical formulation;
              conserves total cohesion mass on any input);
      'ignore' Algorithm 1's sequential if/else: the higher-index point of
              the pair takes tied support.
    On tie-free distances all three modes return identical results.
    """
    validate_ties(ties)
    n = D.shape[0]
    if schedule not in ("dense", "tri"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if method == "auto":
        # an explicit tri request pins the kernel pipeline (the only method
        # with a tri schedule); otherwise use the measured crossover
        method = "kernel" if schedule == "tri" else _tuner.method_for(n)
    if method not in ("dense", "pairwise", "triplet", "kernel"):
        raise ValueError(f"unknown method {method!r}")
    if schedule == "tri" and method != "kernel":
        raise ValueError(
            f"schedule='tri' is only available for method='kernel', got {method!r}"
        )
    if method == "dense":
        D = jnp.asarray(D, jnp.float32)  # explicit boundary cast (see module doc)
        C = _pairwise.pald_dense(D, z_chunk=z_chunk, normalize=False, ties=ties)
        return C / max(n - 1, 1) if normalize else C
    if block == "auto":
        pass_ = {"pairwise": "pald", "triplet": "pald",
                 "kernel": "pald_tri" if schedule == "tri" else "pald"}[method]
        block, bz_auto = _tuner.resolve_blocks(n, pass_, ties=ties)
        if block_z is None:
            block_z = bz_auto
    block = int(block)
    Dp, n0 = pad_distance_matrix(D, block)  # casts to f32 (boundary cast)
    nv = jnp.asarray(n0) if Dp.shape[0] != n0 else None
    # normalization is applied here (not inside the blocked fns) so the padded
    # size never leaks into the 1/(n-1) factor.
    if method == "pairwise":
        C = _pairwise.pald_blocked(Dp, block=block, n_valid=nv, ties=ties)
    elif method == "triplet":
        C = _triplet.pald_block_symmetric(Dp, block=block, n_valid=nv, ties=ties)
    elif method == "kernel":
        from repro.kernels import ops as _kops

        kz = {} if block_z is None else {"block_z": block_z}
        C = _kops.pald(Dp, block=block, n_valid=nv, schedule=schedule,
                       ties=ties, **kz)
    else:
        raise ValueError(f"unknown method {method!r}")
    C = C[:n0, :n0]
    if normalize:
        # max(., 1): n=1 has no pairs and an all-zero C; dividing by zero
        # would turn that into nan
        C = C / max(n0 - 1, 1)
    return C


def local_depths(C: jnp.ndarray) -> jnp.ndarray:
    """l_x = sum_z c_xz (cohesion is *partitioned* local depth)."""
    return jnp.sum(C, axis=1)


# feature-space entry point (fused kernels; see core/features.py).  Imported
# last: features defers its own pald import to call time, so the cycle is
# never executed at module-load time.
from .features import from_features  # noqa: E402,F401
