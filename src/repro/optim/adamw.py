"""AdamW with decoupled weight decay, global-norm clipping and a
warmup-cosine schedule — written from scratch on pytrees (no optax here).

Optimizer state (m, v, and the fp32 params themselves) inherits the
parameter sharding, so under the fsdp/zero3 profiles the full Adam state is
sharded across the data axes (ZeRO-style).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    opt_state: dict,
    step: jnp.ndarray,
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return p - lr * step_, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
