"""Golden-value regression: every path must reproduce the committed fixture.

The fixture (``tests/golden/pald_golden.npz``, built by ``make_golden.py``)
holds a fixed 24-point dataset, its exact float64 distance matrix, and the
cohesion matrix from the O(n^3) entry-wise reference.  Property tests and
cross-method agreement can drift *together*; this file pins the absolute
values, so a silent semantics change in any kernel fails loudly.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import features, pald, reference

_GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "pald_golden.npz")

# float32 tolerance: the optimized paths compare/accumulate in f32; on the
# fixture's well-separated data they agree with the f64 oracle to ~1e-7
ATOL, RTOL = 1e-6, 1e-6


@pytest.fixture(scope="module")
def golden():
    with np.load(_GOLDEN) as z:
        return {k: z[k] for k in z.files}


def test_fixture_is_self_consistent(golden):
    """The committed C really is the reference of the committed D (guards
    against a stale or hand-edited fixture)."""
    C = reference.pald_pairwise_reference(golden["D"], ties="ignore",
                                          normalize=True)
    np.testing.assert_array_equal(C, golden["C"])
    n = golden["D"].shape[0]
    assert golden["C"].sum() == pytest.approx(n / 2, rel=1e-9)


@pytest.mark.parametrize("method,schedule", [
    ("dense", "dense"),
    ("pairwise", "dense"),
    ("triplet", "dense"),
    ("kernel", "dense"),
    ("kernel", "tri"),
])
def test_methods_reproduce_golden(golden, method, schedule):
    C = np.asarray(pald.cohesion(jnp.asarray(golden["D"]), method=method,
                                 schedule=schedule, block=16))
    np.testing.assert_allclose(C, golden["C"], rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_fused_reproduces_golden(golden, impl):
    """The fused path recomputes D from X in f32 (dot-product form); on the
    fixture's separated data this stays within float32 tolerance of the
    f64-distance golden values."""
    C = np.asarray(pald.from_features(jnp.asarray(golden["X"]),
                                      metric="euclidean", block=16,
                                      block_z=16, impl=impl))
    np.testing.assert_allclose(C, golden["C"], rtol=1e-5, atol=1e-5)


def test_cdist_reproduces_golden_distances(golden):
    # the dot-product form ||x||^2+||y||^2-2xy cancels catastrophically for
    # far-from-origin points, costing a few f32 ulps vs the f64 direct form
    D = np.asarray(features.cdist_reference(golden["X"], metric="euclidean"))
    np.testing.assert_allclose(D, golden["D"], rtol=1e-4, atol=1e-5)
