"""Entry-wise reference implementations of PaLD (Algorithms 1 and 2).

These mirror the paper's pseudocode as directly as possible and serve as the
correctness oracles for every optimized path (blocked jnp, Pallas kernels,
distributed shard_map). They are O(n^3) python loops over numpy arrays and are
only intended for n up to a few hundred.

Semantics (documented in DESIGN.md §9; implemented for the optimized paths
by the shared predicates in ``core/ties.py``):
  * ``ties='drop'`` (the pipeline default): strict ``<`` comparisons,
    matching the paper's optimized code which "ignores equality in
    pairwise/triplet distance comparisons" — both strict masks are false on
    a tie, so the tied z supports neither point;
  * ``ties='split'`` implements the theoretical formulation where support is
    split 0.5/0.5 on exact distance ties, INCLUDING the focus-size pass: a z
    exactly on the focus boundary (d_xz == d_xy or d_yz == d_xy) joins the
    focus with weight 0.5, so U is fractional;
  * ``ties='ignore'`` is Algorithm 1's sequential if/else: on a support tie
    the higher-index point wins (the else-branch assigns y, the loop runs
    x < y);
  * ``normalize=True`` applies the 1/(n-1) factor of Eq. (3.3) so that row
    sums of C equal the local depths l_x.
"""
from __future__ import annotations

import numpy as np

from .ties import DEFAULT_TIES, validate_ties

__all__ = [
    "pald_pairwise_reference",
    "pald_triplet_reference",
    "local_focus_reference",
]


def _half_step(d: np.ndarray, thr: float) -> np.ndarray:
    """h(d, thr) = 1 if d < thr, 0.5 if d == thr, else 0 (split-mode weight)."""
    return np.where(d < thr, 1.0, np.where(d == thr, 0.5, 0.0))


def local_focus_reference(D: np.ndarray, *, ties: str = DEFAULT_TIES) -> np.ndarray:
    """Local-focus size matrix U (Algorithm 1, lines 3-6).

    Strict modes ('drop', 'ignore'):
    U[x, y] = |{z : d_xz < d_xy or d_yz < d_xy}| for x != y.  Both x and y
    are always members (d_xx = 0 < d_xy), so U >= 2 off-diagonal for positive
    distances.  'split': boundary ties join with weight 0.5, so U is a
    fractional (multiple-of-0.5) count.  The diagonal is left at 0 and is
    never used.
    """
    validate_ties(ties)
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    U = np.zeros((n, n), dtype=np.float64)
    for x in range(n):
        for y in range(n):
            if x == y:
                continue
            dxy = D[x, y]
            if ties == "split":
                U[x, y] = float(np.sum(
                    np.maximum(_half_step(D[x, :], dxy), _half_step(D[y, :], dxy))
                ))
            else:
                U[x, y] = float(np.sum((D[x, :] < dxy) | (D[y, :] < dxy)))
    return U


def pald_pairwise_reference(
    D: np.ndarray, *, ties: str = DEFAULT_TIES, normalize: bool = False
) -> np.ndarray:
    """Algorithm 1 (pairwise sequential), entry-wise.

    ties='drop'    -> (default) exact ties support neither point: the two
                      strict masks (d_xz < d_yz) and (d_yz < d_xz) are both
                      false on a tie -- the vector analogue of the paper's
                      "ignoring equality in distance comparisons".
    ties='split'   -> exact ties split support 0.5/0.5 (theoretical PaLD /
                      generalized PaLD triplet weights), and a z exactly on
                      the focus boundary joins the focus with weight 0.5.
    ties='ignore'  -> strict focus; on a support tie d_xz == d_yz the
                      support goes to y (the else branch), exactly as
                      Algorithm 1's sequential control flow.

    All optimized paths (blocked jnp, Pallas kernels + fallbacks, fused,
    distributed) match this oracle entry-wise for the SAME ``ties`` mode —
    enforced by tests/test_conformance.py and tests/test_ties.py.
    """
    validate_ties(ties)
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    C = np.zeros((n, n), dtype=np.float64)
    for x in range(n - 1):
        for y in range(x + 1, n):
            dxy = D[x, y]
            if ties == "split":
                m = np.maximum(_half_step(D[x, :], dxy), _half_step(D[y, :], dxy))
                u = float(m.sum())
                if u == 0.0:
                    continue
                w = 1.0 / u
                for z in range(n):
                    if m[z] == 0.0:
                        continue
                    if D[x, z] < D[y, z]:
                        C[x, z] += m[z] * w
                    elif D[y, z] < D[x, z]:
                        C[y, z] += m[z] * w
                    else:
                        C[x, z] += 0.5 * m[z] * w
                        C[y, z] += 0.5 * m[z] * w
                continue
            infocus = (D[x, :] < dxy) | (D[y, :] < dxy)
            u = int(np.sum(infocus))
            if u == 0:
                continue
            w = 1.0 / u
            for z in range(n):
                if not infocus[z]:
                    continue
                if D[x, z] == D[y, z]:
                    if ties == "ignore":
                        C[y, z] += w
                    # 'drop': neither
                elif D[x, z] < D[y, z]:
                    C[x, z] += w
                else:
                    C[y, z] += w
    if normalize:
        C /= max(n - 1, 1)  # n=1: no pairs, C stays zero (not nan)
    return C


def pald_triplet_reference(D: np.ndarray, *, normalize: bool = False) -> np.ndarray:
    """Algorithm 2 (triplet sequential), entry-wise, ties ignored.

    Initializes U = 2 off-diagonal (each pair's two endpoints), then for each
    unordered triplet attributes focus membership / cohesion support to the
    two non-minimal pairs.  Matches pald_pairwise_reference(ties='ignore')
    on distance matrices without exact ties.
    """
    D = np.asarray(D, dtype=np.float64)
    n = D.shape[0]
    U = np.full((n, n), 2.0)
    np.fill_diagonal(U, 0.0)
    for x in range(n - 1):
        for y in range(x + 1, n):
            for z in range(y + 1, n):
                dxy, dxz, dyz = D[x, y], D[x, z], D[y, z]
                if dxy < dxz and dxy < dyz:      # (x, y) closest
                    U[x, z] += 1
                    U[z, x] += 1
                    U[y, z] += 1
                    U[z, y] += 1
                elif dxz < dyz:                  # (x, z) closest
                    U[x, y] += 1
                    U[y, x] += 1
                    U[y, z] += 1
                    U[z, y] += 1
                else:                            # (y, z) closest
                    U[x, y] += 1
                    U[y, x] += 1
                    U[x, z] += 1
                    U[z, x] += 1
    C = np.zeros((n, n), dtype=np.float64)
    for x in range(n - 1):
        for y in range(x + 1, n):
            # z in {x, y} contributions of Algorithm 1's z-loop: z=x supports x
            # (d_xx=0 < d_yx) and z=y supports y -- Algorithm 2's triplet loop
            # only covers z > y, so add the endpoint support explicitly.
            C[x, x] += 1.0 / U[x, y]
            C[y, y] += 1.0 / U[x, y]
            for z in range(n):
                if z == x or z == y:
                    continue
                dxy, dxz, dyz = D[x, y], D[x, z], D[y, z]
                if dxy < dxz and dxy < dyz:
                    continue                     # z outside the (x,y) focus
                if dxz < dyz:
                    C[x, z] += 1.0 / U[x, y]
                else:
                    C[y, z] += 1.0 / U[x, y]
    if normalize:
        C /= max(n - 1, 1)
    return C
