"""Pure-jnp oracles for the PaLD Pallas kernels.

Kept deliberately naive (one O(n^3) broadcast, z-chunked) so kernel tests
compare against straight-line jnp semantics, independent of the blocked
implementations in repro.core.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["focus_ref", "cohesion_ref", "weights_ref"]


def focus_ref(D: jnp.ndarray) -> jnp.ndarray:
    D = D.astype(jnp.float32)
    m = (D[:, None, :] < D[:, :, None]) | (D[None, :, :] < D[:, :, None])
    return jnp.sum(m, axis=-1).astype(jnp.float32)


def weights_ref(U: jnp.ndarray, n_valid=None) -> jnp.ndarray:
    n = U.shape[0]
    eye = jnp.eye(n, dtype=bool)
    W = jnp.where(eye | (U == 0), 0.0, 1.0 / jnp.where(U == 0, 1.0, U))
    if n_valid is not None:
        valid = jnp.arange(n) < n_valid
        W = W * valid[:, None] * valid[None, :]
    return W.astype(jnp.float32)


def cohesion_ref(D: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    D = D.astype(jnp.float32)
    # g[x, y, z] = (d_xz < d_yz) & (d_xz < d_xy)
    g = (D[:, None, :] < D[None, :, :]) & (D[:, None, :] < D[:, :, None])
    return jnp.einsum("xyz,xy->xz", g.astype(jnp.float32), W.astype(jnp.float32))
