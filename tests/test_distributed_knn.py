"""Mesh-sharded knn PaLD: bitwise conformance vs the single-device fused path.

The conformance matrix crosses every strategy x mesh size x k x weight
functional on a tie-heavy integer feature matrix whose n is NOT divisible
by the larger meshes (uneven shards + pad lanes exercised in every cell).
Every assertion is exact equality — the sharded bodies reproduce the
single-device fused select->cohere pipeline bit for bit, including the
stable (value, index) selection order under exact distance ties.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distributed_knn as dknn
from repro.core import knn as knnmod
from repro.core import pald
from repro.kernels import ops
from repro.launch import mesh as meshlib

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)

N, DIM = 50, 4
WEIGHTS = ("drop", "split", "ignore")
K_VALUES = (1, 33, N - 1)  # tiny, mid, and the k >= n-1 dense boundary

# p in {1, 2, 4, 8}; 50 % 4 != 0 and 50 % 8 != 0 -> uneven shards at the
# larger meshes.  The 2d strategy needs >= 2 axes, so its p-ladder uses
# (1,1), (1,2), (2,2), (4,2) — the last two with pr != 1 exercise the
# strided candidate split.
MESH_SHAPES = {
    "allgather": [(1,), (2,), (4,), (8,)],
    "ring": [(1,), (2,), (4,), (8,)],
    "2d": [(1, 1), (1, 2), (2, 2), (4, 2)],
}
CELLS = [
    (strategy, shape, k, weight)
    for strategy, shapes in MESH_SHAPES.items()
    for shape in shapes
    for k in K_VALUES
    for weight in WEIGHTS
]


def _mesh(shape):
    return meshlib.make_test_mesh(
        shape, tuple(f"ax{i}" for i in range(len(shape))))


@pytest.fixture(scope="module")
def X():
    # integers 0..3 -> massive exact distance ties in every metric
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.integers(0, 4, (N, DIM)), jnp.float32)


@pytest.fixture(scope="module")
def single_device(X):
    """Single-device fused reference, cached per (k, weight) cell."""
    cache = {}

    def get(k, weight):
        if (k, weight) not in cache:
            cache[(k, weight)] = np.asarray(
                pald.from_features(X, method="knn", k=k, weight=weight))
        return cache[(k, weight)]

    return get


# ---------------------------------------------------------------------------
# the conformance matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy,shape,k,weight", CELLS)
def test_conformance_bitwise(X, single_device, strategy, shape, k, weight):
    C = np.asarray(pald.from_features(
        X, method="knn", k=k, weight=weight, mesh=_mesh(shape),
        strategy=strategy))
    np.testing.assert_array_equal(C, single_device(k, weight))


# ---------------------------------------------------------------------------
# module-level contract (graph + values, bypassing the engine)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy,shape", [
    ("allgather", (4,)), ("ring", (8,)), ("2d", (2, 2)),
])
def test_sharded_graph_matches_fused(X, strategy, shape):
    """Neighbor indices, distances AND cohesion values — not just the
    scattered matrix — must be identical to the single-device kernel."""
    gr, vr = ops.select_cohere(X, k=7, impl="jnp", normalize=True)
    gs, vs = dknn.pald_knn_sharded(X, _mesh(shape), k=7, strategy=strategy)
    np.testing.assert_array_equal(np.asarray(gs.indices),
                                  np.asarray(gr.indices))
    np.testing.assert_array_equal(np.asarray(gs.distances),
                                  np.asarray(gr.distances))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vr))


@pytest.mark.parametrize("strategy,shape", [
    ("allgather", (4,)), ("ring", (4,)), ("2d", (2, 2)),
])
def test_sharded_k_full_runs_sharded(X, strategy, shape):
    """k = n-1 through the sharded bodies themselves (the engine facade
    short-circuits this to dense; the module must still answer exactly)."""
    gr, vr = ops.select_cohere(X, k=N - 1, impl="jnp", normalize=True)
    gs, vs = dknn.pald_knn_sharded(X, _mesh(shape), k=N - 1,
                                   strategy=strategy)
    np.testing.assert_array_equal(np.asarray(gs.indices),
                                  np.asarray(gr.indices))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vr))


def test_k_clamped_and_short_circuit(X):
    """The engine's k >= n-1 dense short-circuit stays in force on a mesh
    plan: the result equals the dense method bitwise."""
    mesh = _mesh((2, 2))
    C = np.asarray(pald.from_features(X, method="knn", k=N - 1, mesh=mesh))
    Cd = np.asarray(pald.from_features(X, method="dense"))
    np.testing.assert_array_equal(C, Cd)


@pytest.mark.parametrize("n", [7, 13, 53])
def test_uneven_prime_n(n):
    """Prime-ish n on p=4: every shard padded differently, pad lanes must
    contribute nothing."""
    rng = np.random.default_rng(n)
    Xp = jnp.asarray(rng.integers(0, 3, (n, 3)), jnp.float32)
    k = min(5, n - 1)
    ref = np.asarray(pald.from_features(Xp, method="knn", k=k))
    C = np.asarray(pald.from_features(
        Xp, method="knn", k=k, mesh=_mesh((4,)), strategy="ring"))
    np.testing.assert_array_equal(C, ref)


@pytest.mark.parametrize("metric", ["sqeuclidean", "manhattan"])
def test_other_metrics(X, metric):
    ref = np.asarray(pald.from_features(X, method="knn", k=9, metric=metric))
    C = np.asarray(pald.from_features(
        X, method="knn", k=9, metric=metric, mesh=_mesh((4,)),
        strategy="allgather"))
    np.testing.assert_array_equal(C, ref)


# ---------------------------------------------------------------------------
# plan surface
# ---------------------------------------------------------------------------
def test_explain_reports_mesh(X):
    mesh = _mesh((2, 4))
    p = pald.plan(X, kind="features", k=7, mesh=mesh)
    e = p.explain()
    assert e["mesh"] == (2, 4)
    assert e["mesh_axes"] == ("ax0", "ax1")
    assert e["strategy"] == "2d"  # auto on a 2-axis mesh
    assert e["shard_rows"] * 8 >= N
    est = e["comm_estimate"]
    assert est["strategy"] == "2d" and est["p"] == 8
    assert est["per_device_words"] > 0
    assert set(est["breakdown"]) == {
        "allgather_x", "allgather_ids", "rowcand_slabs", "merge_partials"}


def test_explain_off_mesh_is_none(X):
    e = pald.plan(X, kind="features", k=7).explain()
    assert e["mesh"] is None and e["strategy"] is None
    assert e["shard_rows"] is None and e["comm_estimate"] is None


def test_auto_strategy_1d_is_ring(X):
    p = pald.plan(X, kind="features", k=7, mesh=_mesh((4,)))
    assert p.strategy == "ring"


def test_validation_errors(X):
    mesh1 = _mesh((4,))
    with pytest.raises(ValueError, match="strategy"):
        pald.plan(X, kind="features", k=7, strategy="ring")  # no mesh
    with pytest.raises(ValueError, match="mesh"):
        pald.plan(X, kind="features", method="fused", mesh=mesh1)
    with pytest.raises(ValueError, match="batch"):
        pald.plan(X, kind="features", k=7, mesh=mesh1, batch=2)
    with pytest.raises(ValueError, match="2d"):
        pald.plan(X, kind="features", k=7, mesh=mesh1, strategy="2d")
    with pytest.raises(ValueError, match="strategy"):
        pald.plan(X, kind="features", k=7, mesh=mesh1, strategy="torus")
    with pytest.raises(ValueError):
        dknn.pald_knn_sharded(X, mesh1, k=7, strategy="torus")
    with pytest.raises(ValueError):
        dknn.pald_knn_sharded(X, mesh1, k=7, metric="nope")


def test_shard_shape_resolution():
    chunk, quantum, m = dknn.resolve_shard_shapes(50, p=4, chunk=64)
    assert chunk == 13 and quantum == 52 and m == 52  # clamped to ceil(n/p)
    chunk, quantum, m = dknn.resolve_shard_shapes(50, p=4, chunk=8)
    assert chunk == 8 and quantum == 32 and m == 64
    assert m % 4 == 0 and (m // 4) % chunk == 0


def test_comm_estimate_model():
    est = dknn.comm_estimate("ring", n=1000, d=16, k=8, p=8)
    # the docstring's claim: ring moves 2*(p-1)/p * n*d words total
    assert est["per_device_words"] == 2 * 7 * 125 * 16
    est = dknn.comm_estimate("allgather", n=1000, d=16, k=8, p=8)
    assert est["per_device_words"] == 7 * 125 * 16
    with pytest.raises(ValueError):
        dknn.comm_estimate("torus", n=10, d=2, k=1, p=2)


# ---------------------------------------------------------------------------
# tuning-cache mesh keys
# ---------------------------------------------------------------------------
def test_tuning_key_gains_p(tmp_path, monkeypatch, X):
    from repro.tuning import autotune as tuner

    assert tuner._pass_key("pald_topk", 4, k=7, p=4) == "pald_topk:k7:d4:p4"
    assert tuner._pass_key("pald_topk", 4, k=7, p=1) == "pald_topk:k7:d4"
    assert tuner._pass_key("pald_topk", 4, k=7) == "pald_topk:k7:d4"
