"""qwen2.5-14b — 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    qkv_bias=True,
    rope_theta=1000000.0,
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=2,
    subquadratic=False,
)
