"""Triangular-schedule Pallas kernel for PaLD pass 2 (block-symmetric).

The dense cohesion kernel (pald_cohesion) runs the full (nx, nz, ny) grid:
every ordered (X, Y) block pair is visited and only the x-role update

    C[x, z] += (d_xz < d_yz) & (d_xz < d_xy) * W[x, y]

is applied.  Cohesion support is a property of the *unordered* pair, so half
of those visits redo comparisons whose outcome is determined by the mirrored
visit.  This variant is the pass-2 counterpart of ``pald_focus_tri``
(DESIGN.md §4.3): only the nb(nb+1)/2 upper-triangular (X, Y) block pairs are
enumerated — scalar-prefetched (xb, yb) index arrays via
``pltpu.PrefetchScalarGridSpec`` — and each off-diagonal visit performs BOTH
role updates:

    x-role:  C[x, z] += support_weight(d_xz, d_yz, d_xy) * W[x, y]
    y-role:  C[y, z] += support_weight(d_yz, d_xz, d_xy) * W[x, y]

with the support contribution supplied by the resolved weight functional
shared across every path (``core/weights.py``).
Before PR 3 the y-role reused the x-role's comparison through its complement
(ties -> y, i.e. ``ties='ignore'``) while diagonal blocks ran the one-sided
strict x-role (``ties='drop'``), so the schedule matched *neither* reference
on tied input — the shared helper computes both roles explicitly in the
requested mode instead, with the global block indices (already prefetched
for the index maps) providing the ``ties='ignore'`` index tiebreak.

Accumulation layout (grid = (nz, npairs), pairs innermost, x-major order):

* x-role → ``Cx`` (n, n): output block (block, block_z) at (xs[t], k).  With
  pairs sorted x-major, all visits to one Cx block are consecutive grid
  steps, so the block stays resident in VMEM and is accumulated in-kernel
  (same discipline as the dense kernel's innermost y axis).
* y-role → ``Cy`` (n, block_z * nz = n): output block (n, block_z) at
  (0, k) — the full column slab for the current z-chunk.  Its index map is
  constant in t, so it too is revisited only consecutively; rows ys[t] are
  updated in place with a dynamic-slice store.  VMEM cost n * block_z
  floats, which bounds block_z for large n (the autotuner's job).

Diagonal blocks (xb == yb) apply the dense one-sided x-role over the full
(block, block) pair square — that already covers both orders of every
in-block pair — and skip the y-role.

C = Cx + Cy is one O(n^2) merge outside the kernel.  Comparison count drops
from 2 n^3 (dense ordered grid) to ~1.5 n^3 with half the D/W block traffic.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.weights import DEFAULT_TIES, resolve_weight, support_weight

__all__ = ["cohesion_tri_pallas"]


def _cohesion_tri_kernel(xs_ref, ys_ref, dxz_ref, dyz_ref, dxy_ref, w_ref,
                         cx_ref, cy_ref, *, ties):
    t = pl.program_id(1)
    xb = xs_ref[t]
    yb = ys_ref[t]
    xprev = xs_ref[jnp.maximum(t - 1, 0)]

    @pl.when((t == 0) | (xb != xprev))
    def _init_cx():
        cx_ref[...] = jnp.zeros_like(cx_ref)

    @pl.when(t == 0)
    def _init_cy():
        cy_ref[...] = jnp.zeros_like(cy_ref)

    dxz = dxz_ref[...]  # (b, bz)  D[X, z-chunk]
    dyz = dyz_ref[...]  # (b, bz)  D[Y, z-chunk]
    dxy = dxy_ref[...]  # (b, b)   D[X, Y]
    w = w_ref[...]      # (b, b)   W[X, Y]
    b = dxy.shape[1]
    is_diag = xb == yb

    def body(y, accs):
        acc_x, acc_y = accs
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)   # (1, bz) d_yz
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)   # (b, 1)  d_xy
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)      # (b, 1)
        xw = yw = None
        if ties.needs_index_tiebreak:
            # global-index tiebreak from the prefetched block coordinates; on
            # diagonal blocks the one-sided x-role visits both orders of every
            # in-block pair, so xw alone implements the mode there
            xg = xb * b + jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
            yg = yb * b + y
            xw, yw = xg > yg, yg > xg
        gx = support_weight(dxz, row, thr, ties, xw)            # (b, bz)
        acc_x = acc_x + gx * wy
        # y-role: one output row, reduced over the x axis
        gy = support_weight(row, dxz, thr, ties, yw)            # (b, bz)
        ry = jnp.sum(gy * wy, axis=0, keepdims=True)
        acc_y = jax.lax.dynamic_update_slice_in_dim(acc_y, ry, y, axis=0)
        return acc_x, acc_y

    bx, bz = dxz.shape
    add_x, add_y = jax.lax.fori_loop(
        0, b, body,
        (jnp.zeros((bx, bz), jnp.float32), jnp.zeros((b, bz), jnp.float32)),
    )
    cx_ref[...] += add_x

    @pl.when(jnp.logical_not(is_diag))
    def _update_cy():
        start = yb * b
        cy_ref[pl.ds(start, b), :] += add_y


@functools.partial(jax.jit, static_argnames=("block", "block_z", "interpret",
                                             "ties"))
def cohesion_tri_pallas(
    D: jnp.ndarray,
    W: jnp.ndarray,
    *,
    block: int = 128,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """C (n, n) via the upper-triangular block schedule (square case only)."""
    ties = resolve_weight(ties)
    n = D.shape[0]
    assert W.shape == (n, n)
    assert n % block == 0 and n % block_z == 0
    nb = n // block
    xs_np, ys_np = np.triu_indices(nb)   # row-major: xs non-decreasing
    npairs = xs_np.shape[0]
    xs = jnp.asarray(xs_np, jnp.int32)
    ys = jnp.asarray(ys_np, jnp.int32)
    D = D.astype(jnp.float32)
    W = W.astype(jnp.float32)

    grid = (n // block_z, npairs)        # z-chunk outer, pairs inner
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # D[X, z-chunk]
            pl.BlockSpec((block, block_z), lambda k, t, xs, ys: (xs[t], k)),
            # D[Y, z-chunk]
            pl.BlockSpec((block, block_z), lambda k, t, xs, ys: (ys[t], k)),
            # D[X, Y]
            pl.BlockSpec((block, block), lambda k, t, xs, ys: (xs[t], ys[t])),
            # W[X, Y]
            pl.BlockSpec((block, block), lambda k, t, xs, ys: (xs[t], ys[t])),
        ],
        out_specs=[
            # x-role: row block of Cx, consecutive revisits within an x-run
            pl.BlockSpec((block, block_z), lambda k, t, xs, ys: (xs[t], k)),
            # y-role: whole column slab of Cy, resident across the k-th sweep
            pl.BlockSpec((n, block_z), lambda k, t, xs, ys: (0, k)),
        ],
    )
    Cx, Cy = pl.pallas_call(
        functools.partial(_cohesion_tri_kernel, ties=ties),
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, n), jnp.float32),
        ],
        interpret=interpret,
    )(xs, ys, D, D, D, W)
    return Cx + Cy
