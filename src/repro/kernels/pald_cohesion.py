"""Pallas TPU kernel for PaLD pass 2: cohesion accumulation.

    C[x, z] = sum_y support_weight(D[x,z], D[y,z], D[x,y]) * W[x,y]

with W = 1/U (zero diagonal / padded entries; computed outside the kernel so
the reciprocal is done once — the paper's "precompute reciprocals" trick)
and the support contribution supplied by the resolved weight functional
shared with every other path (``core/weights.py``; the default
``ties='drop'`` is the classic strict ``(d_xz < d_yz) & (d_xz < d_xy)``).

Grid (nx, nz, ny) with the y-reduction innermost: the output block C[X, Z]
stays resident in VMEM across all y steps.  The kernel updates unit-stride
(bx, bz) rows of C — the TPU translation of the paper's "updating columns of
C instead" stride-1 optimization (their C is updated column-wise because the
z loop streams columns; our block layout makes the streamed dim contiguous).

Functionals declaring ``needs_index_tiebreak`` (the built-in ``'ignore'``)
need the global-index x>y predicate.  Two equivalent static specs:

- ``XW`` (mx, my) float32, 1.0 where global index x > global index y,
  riding the same BlockSpec as W — for callers who already hold such a
  tile (distributed shard bodies reuse their per-shard derivation);
- ``xw_offsets=(row_off, col_off)`` — the kernel derives the predicate
  per (bx, by) tile from grid position plus the static offsets via a
  row iota, so no (mx, my) tiebreak array ever materializes.  This is
  the default route for the sequential square case (offsets (0, 0)).

VMEM = D_XZ + C_XZ + D_YZ + D_XY + W_XY (+ XW_XY for the explicit-XW
route) = 3*bx*bz + 2*bx*by (+ bx*by) floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.weights import DEFAULT_TIES, resolve_weight, support_weight

__all__ = ["cohesion_pallas"]


def _cohesion_kernel(dxz_ref, dyz_ref, dxy_ref, w_ref, c_ref, *, ties):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]  # (bx, bz)
    dyz = dyz_ref[...]  # (by, bz)
    dxy = dxy_ref[...]  # (bx, by)
    w = w_ref[...]      # (bx, by)
    by = dxy.shape[1]

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)   # (1, bz)  d_yz
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)   # (bx, 1) d_xy
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)      # (bx, 1)
        g = support_weight(dxz, row, thr, ties)                 # (bx, bz)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


def _cohesion_kernel_xw(dxz_ref, dyz_ref, dxy_ref, w_ref, xw_ref, c_ref, *, ties):
    """Index-tiebreak variant with an explicit (bx, by) tiebreak tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]
    dyz = dyz_ref[...]
    dxy = dxy_ref[...]
    w = w_ref[...]
    xw = xw_ref[...]    # (bx, by) 1.0 where global x index > global y index
    by = dxy.shape[1]

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)
        xwy = jax.lax.dynamic_slice_in_dim(xw, y, 1, axis=1) > 0.5  # (bx, 1)
        g = support_weight(dxz, row, thr, ties, xwy)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


def _cohesion_kernel_iota(dxz_ref, dyz_ref, dxy_ref, w_ref, c_ref, *, ties,
                          row_off, col_off, block_x, block_y):
    """Index-tiebreak variant deriving x>y per tile from grid position.

    Global x index of tile row r is ``row_off + i*block_x + r``; global y
    index of reduction lane y is ``col_off + k*block_y + y`` — a row iota
    plus two scalars, so no dense (mx, my) tiebreak array is ever built.
    """
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]
    dyz = dyz_ref[...]
    dxy = dxy_ref[...]
    w = w_ref[...]
    by = dxy.shape[1]
    xg = row_off + i * block_x + jax.lax.broadcasted_iota(
        jnp.int32, (dxz.shape[0], 1), 0)                        # (bx, 1)
    ybase = col_off + k * block_y

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)
        xwy = xg > ybase + y                                    # (bx, 1)
        g = support_weight(dxz, row, thr, ties, xwy)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


@functools.partial(jax.jit, static_argnames=("block_x", "block_z", "block_y",
                                             "interpret", "ties",
                                             "xw_offsets"))
def cohesion_general_pallas(
    DXZ: jnp.ndarray,  # (mx, mz)
    DYZ: jnp.ndarray,  # (my, mz)
    DXY: jnp.ndarray,  # (mx, my)
    W: jnp.ndarray,    # (mx, my)
    XW: jnp.ndarray | None = None,  # (mx, my) explicit tiebreak tile
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
    ties=DEFAULT_TIES,
    xw_offsets: tuple[int, int] | None = None,
) -> jnp.ndarray:
    """C (mx, mz) = sum_y support_weight(DXZ, DYZ[y], DXY[:,y]) * W[:,y].

    Rectangular form for distributed per-device compute; the square
    sequential case passes D three times.  Functionals declaring
    ``needs_index_tiebreak`` additionally require either ``XW`` (1.0 where
    global x index > global y index) or static ``xw_offsets=(row_off,
    col_off)`` global offsets from which the kernel derives the predicate
    per tile.
    """
    wfun = resolve_weight(ties)
    mx, mz = DXZ.shape
    my = DYZ.shape[0]
    assert DYZ.shape[1] == mz and DXY.shape == (mx, my) and W.shape == (mx, my)
    assert mx % block_x == 0 and mz % block_z == 0 and my % block_y == 0
    grid = (mx // block_x, mz // block_z, my // block_y)
    pair_spec = pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, k))
    in_specs = [
        pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),  # DXZ
        pl.BlockSpec((block_y, block_z), lambda i, j, k: (k, j)),  # DYZ
        pair_spec,                                                 # DXY
        pair_spec,                                                 # W
    ]
    args = [DXZ.astype(jnp.float32), DYZ.astype(jnp.float32),
            DXY.astype(jnp.float32), W.astype(jnp.float32)]
    if wfun.needs_index_tiebreak:
        if XW is not None:
            assert XW.shape == (mx, my)
            in_specs.append(pair_spec)                             # XW
            args.append(XW.astype(jnp.float32))
            kernel = functools.partial(_cohesion_kernel_xw, ties=wfun)
        elif xw_offsets is not None:
            kernel = functools.partial(
                _cohesion_kernel_iota, ties=wfun,
                row_off=int(xw_offsets[0]), col_off=int(xw_offsets[1]),
                block_x=block_x, block_y=block_y)
        else:
            raise ValueError(f"weight {wfun.name!r} needs XW or xw_offsets "
                             "(global-index tiebreak)")
    else:
        kernel = functools.partial(_cohesion_kernel, ties=wfun)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mx, mz), jnp.float32),
        interpret=interpret,
    )(*args)


def cohesion_pallas(
    D: jnp.ndarray,
    W: jnp.ndarray,
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
    ties=DEFAULT_TIES,
    XW: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Square cohesion matrix (un-normalized, sequential case)."""
    offs = (0, 0) if XW is None else None
    return cohesion_general_pallas(
        D, D, D, W, XW, block_x=block_x, block_z=block_z, block_y=block_y,
        interpret=interpret, ties=ties, xw_offsets=offs,
    )
