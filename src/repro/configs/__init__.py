"""Architecture configs (one module per assigned arch) + registry.

``get(arch_id)`` resolves an assigned-pool id like ``"qwen2.5-14b"`` to its
``ModelConfig``; ``ARCHS`` lists all ten.  ``reduced(get(id))`` gives the
same-family smoke config used by the per-arch CPU tests.
"""
from __future__ import annotations

from .base import (  # noqa: F401
    LayerSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    reduced,
    runnable,
)

from . import (  # noqa: E402
    gemma2_2b,
    gemma2_9b,
    granite_moe_1b,
    internvl2_1b,
    jamba15_398b,
    llama32_3b,
    mamba2_780m,
    musicgen_medium,
    phi35_moe_42b,
    qwen25_14b,
)

_MODULES = (
    phi35_moe_42b,
    granite_moe_1b,
    mamba2_780m,
    qwen25_14b,
    llama32_3b,
    gemma2_2b,
    gemma2_9b,
    jamba15_398b,
    musicgen_medium,
    internvl2_1b,
)

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCHS: tuple[str, ...] = tuple(REGISTRY)


def get(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(ARCHS)}"
        ) from None
