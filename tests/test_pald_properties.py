"""Hypothesis property tests for PaLD system invariants.

PaLD's defining property is that cohesion depends only on the *relative
order* of distances — these tests pin that down mechanically, plus mass
conservation and symmetry-group equivariance.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp

from repro.core import pald

from conftest import euclidean_distance_matrix


def _points(draw, nmin=4, nmax=24, dim=3):
    n = draw(st.integers(nmin, nmax))
    flat = draw(
        st.lists(
            st.floats(-100, 100, allow_nan=False, width=32),
            min_size=n * dim, max_size=n * dim,
        )
    )
    X = np.asarray(flat, np.float64).reshape(n, dim)
    # jitter deterministically to kill exact duplicates / ties
    X = X + np.arange(n * dim).reshape(n, dim) * 1e-3
    return X


pointsets = st.builds(lambda seed, n: None)  # placeholder, built below


@st.composite
def distance_matrices(draw):
    X = _points(draw)
    return euclidean_distance_matrix(X)


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_total_mass_is_half_n(D):
    """Σ c_xz = C(n,2)/(n-1) = n/2 — exactly, but only for TIE-FREE input
    (the optimized paths drop exact ties; hypothesis found the collinear
    evenly-spaced counterexample, hence the assume)."""
    n = D.shape[0]
    iu = np.triu_indices(n, 1)
    # any duplicated distance value breaks the exact identity
    assume(len(np.unique(D[iu])) == len(iu[0]))
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert abs(C.sum() - n / 2) < 1e-3 * n


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_monotone_transform_invariance(D):
    """C depends only on the ordering of distances."""
    C1 = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    D2 = np.sqrt(D) * 3.0 + np.tanh(D)  # strictly increasing on [0, inf)
    np.fill_diagonal(D2, 0.0)
    C2 = np.asarray(pald.cohesion(jnp.asarray(D2), method="dense"))
    np.testing.assert_allclose(C1, C2, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(distance_matrices(), st.randoms(use_true_random=False))
def test_permutation_equivariance(D, rnd):
    n = D.shape[0]
    perm = list(range(n))
    rnd.shuffle(perm)
    perm = np.asarray(perm)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    Cp = np.asarray(pald.cohesion(jnp.asarray(D[np.ix_(perm, perm)]), method="dense"))
    np.testing.assert_allclose(Cp, C[np.ix_(perm, perm)], rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(distance_matrices())
def test_methods_agree(D):
    """The blocked pairwise / block-symmetric / kernel paths all agree with
    the dense vectorized formulation on arbitrary inputs."""
    Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    for method in ("pairwise", "triplet", "kernel"):
        C = np.asarray(pald.cohesion(jnp.asarray(D), method=method, block=8))
        np.testing.assert_allclose(C, Cd, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_self_cohesion_dominates_row(D):
    """c_xx >= c_xz for all z: a point always supports itself in every
    focus it belongs to (d_xx = 0 is minimal)."""
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert (np.diag(C)[:, None] >= C - 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_cohesion_nonnegative_bounded(D):
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert (C >= -1e-12).all()
    assert (C <= 1.0 + 1e-9).all()


# ---------------------------------------------------------------------------
# sharded selection (core/distributed_knn) — mesh laws
# ---------------------------------------------------------------------------
import jax  # noqa: E402

_needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 host devices")


@st.composite
def feature_sets(draw, nmin=8, nmax=12, dim=3):
    X = _points(draw, nmin=nmin, nmax=nmax, dim=dim)
    return np.asarray(X, np.float32)


def _shard_graph(X, p, strategy="ring", k=3):
    from repro.core import distributed_knn as dknn
    from repro.launch import mesh as meshlib

    mesh = meshlib.make_test_mesh((p,), ("data",))
    g, v = dknn.pald_knn_sharded(jnp.asarray(X), mesh, k=k,
                                 strategy=strategy)
    return np.asarray(g.indices), np.asarray(g.distances), np.asarray(v)


@_needs_devices
@settings(max_examples=6, deadline=None)
@given(feature_sets())
def test_sharded_shard_count_invariance(X):
    """The selected graph and cohesion values are identical for ANY shard
    count — sharding is a data-movement choice, never a semantic one."""
    i1, d1, v1 = _shard_graph(X, 1)
    for p in (2, 4):
        ip, dp, vp = _shard_graph(X, p)
        np.testing.assert_array_equal(ip, i1)
        np.testing.assert_array_equal(dp, d1)
        np.testing.assert_array_equal(vp, v1)


@_needs_devices
@settings(max_examples=6, deadline=None)
@given(feature_sets(), st.randoms(use_true_random=False))
def test_sharded_permutation_equivariance(X, rnd):
    """Permuting the points permutes the selected neighborhoods (as SETS;
    tie-free input via assume) and the cohesion matrix equivariantly."""
    n = X.shape[0]
    D = euclidean_distance_matrix(X)
    iu = np.triu_indices(n, 1)
    assume(len(np.unique(D[iu])) == len(iu[0]))
    perm = list(range(n))
    rnd.shuffle(perm)
    perm = np.asarray(perm)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)

    i0, _, v0 = _shard_graph(X, 4)
    ip, _, vp = _shard_graph(X[perm], 4)
    # row r of the permuted run is point perm[r]; its neighbor ids map
    # back through perm — equal as sets (selection ORDER may differ only
    # under ties, excluded above, so sorted comparison is exact)
    np.testing.assert_array_equal(
        np.sort(perm[ip], axis=1), np.sort(i0[perm], axis=1))
    # cohesion values: same pair algebra, summation order may differ.
    # vals column 0 is the self-lane, columns 1..k follow the graph.
    ids = np.arange(n)
    full0 = np.concatenate([ids[:, None], i0], axis=1)
    fullp = np.concatenate([perm[:, None], perm[ip]], axis=1)
    C0 = np.zeros((n, n), np.float64)
    Cp = np.zeros((n, n), np.float64)
    np.add.at(C0, (np.repeat(ids, full0.shape[1]), full0.reshape(-1)),
              v0.reshape(-1))
    np.add.at(Cp, (np.repeat(perm, fullp.shape[1]), fullp.reshape(-1)),
              vp.reshape(-1))
    np.testing.assert_allclose(Cp, C0, rtol=1e-4, atol=1e-6)


@_needs_devices
@settings(max_examples=6, deadline=None)
@given(st.sampled_from([5, 7, 11, 13, 17, 19, 23]),
       st.integers(0, 2**31 - 1))
def test_sharded_pad_lane_masking(n, seed):
    """Prime-ish n on p=4: the padded shard lanes (up to p-1 whole rows
    plus ragged tails) must never leak into any selected neighborhood or
    cohesion value — bitwise equality with the single-device kernel."""
    from repro.kernels import ops as _ops

    rng = np.random.default_rng(seed)
    X = np.asarray(rng.integers(0, 3, (n, 3)), np.float32)  # ties welcome
    k = min(3, n - 1)
    gr, vr = _ops.select_cohere(jnp.asarray(X), k=k, impl="jnp",
                                normalize=True)
    ip, dp, vp = _shard_graph(X, 4, k=k)
    np.testing.assert_array_equal(ip, np.asarray(gr.indices))
    np.testing.assert_array_equal(dp, np.asarray(gr.distances))
    np.testing.assert_array_equal(vp, np.asarray(vr))
