"""Hypothesis property tests for PaLD system invariants.

PaLD's defining property is that cohesion depends only on the *relative
order* of distances — these tests pin that down mechanically, plus mass
conservation and symmetry-group equivariance.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp

from repro.core import pald

from conftest import euclidean_distance_matrix


def _points(draw, nmin=4, nmax=24, dim=3):
    n = draw(st.integers(nmin, nmax))
    flat = draw(
        st.lists(
            st.floats(-100, 100, allow_nan=False, width=32),
            min_size=n * dim, max_size=n * dim,
        )
    )
    X = np.asarray(flat, np.float64).reshape(n, dim)
    # jitter deterministically to kill exact duplicates / ties
    X = X + np.arange(n * dim).reshape(n, dim) * 1e-3
    return X


pointsets = st.builds(lambda seed, n: None)  # placeholder, built below


@st.composite
def distance_matrices(draw):
    X = _points(draw)
    return euclidean_distance_matrix(X)


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_total_mass_is_half_n(D):
    """Σ c_xz = C(n,2)/(n-1) = n/2 — exactly, but only for TIE-FREE input
    (the optimized paths drop exact ties; hypothesis found the collinear
    evenly-spaced counterexample, hence the assume)."""
    n = D.shape[0]
    iu = np.triu_indices(n, 1)
    # any duplicated distance value breaks the exact identity
    assume(len(np.unique(D[iu])) == len(iu[0]))
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert abs(C.sum() - n / 2) < 1e-3 * n


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_monotone_transform_invariance(D):
    """C depends only on the ordering of distances."""
    C1 = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    D2 = np.sqrt(D) * 3.0 + np.tanh(D)  # strictly increasing on [0, inf)
    np.fill_diagonal(D2, 0.0)
    C2 = np.asarray(pald.cohesion(jnp.asarray(D2), method="dense"))
    np.testing.assert_allclose(C1, C2, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(distance_matrices(), st.randoms(use_true_random=False))
def test_permutation_equivariance(D, rnd):
    n = D.shape[0]
    perm = list(range(n))
    rnd.shuffle(perm)
    perm = np.asarray(perm)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    Cp = np.asarray(pald.cohesion(jnp.asarray(D[np.ix_(perm, perm)]), method="dense"))
    np.testing.assert_allclose(Cp, C[np.ix_(perm, perm)], rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(distance_matrices())
def test_methods_agree(D):
    """The blocked pairwise / block-symmetric / kernel paths all agree with
    the dense vectorized formulation on arbitrary inputs."""
    Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    for method in ("pairwise", "triplet", "kernel"):
        C = np.asarray(pald.cohesion(jnp.asarray(D), method=method, block=8))
        np.testing.assert_allclose(C, Cd, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_self_cohesion_dominates_row(D):
    """c_xx >= c_xz for all z: a point always supports itself in every
    focus it belongs to (d_xx = 0 is minimal)."""
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert (np.diag(C)[:, None] >= C - 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(distance_matrices())
def test_cohesion_nonnegative_bounded(D):
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert (C >= -1e-12).all()
    assert (C <= 1.0 + 1e-9).all()
