"""Model / run configuration system.

A ``ModelConfig`` fully describes one architecture.  Heterogeneous stacks
(gemma2 local/global alternation, jamba attn:mamba 1:7) are expressed as a
repeating ``pattern`` of ``LayerSpec`` entries; the model scans over
``n_layers // len(pattern)`` repeats with the pattern unrolled inside the
scan body, so the HLO stays O(len(pattern)) regardless of depth.

Shapes (the assigned input-shape set) are in ``SHAPES``; each (arch x shape)
cell resolves via ``runnable()`` -- pure-full-attention archs skip long_500k
per the brief (DESIGN.md §6.2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional

Modality = Literal["text", "audio", "vlm"]


@dataclass(frozen=True)
class LayerSpec:
    """One sublayer position within the repeating pattern."""
    mixer: Literal["attn", "mamba"] = "attn"
    ffn: Literal["dense", "moe", "none"] = "dense"
    window: Optional[int] = None  # sliding-window size for attn, None = global


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff: int = 0                 # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # dispatch group size: total one-hot dispatch/combine work is
    # ~1.25·k·T·group_tokens — small-expert configs (granite d_ff=512)
    # want this low or the dispatch einsums rival the expert FLOPs
    group_tokens: int = 512


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256
    expand: int = 2


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    use_post_norm: bool = False             # gemma2 sandwich norms
    scale_embed: bool = False               # gemma2 sqrt(d) embedding scale
    act: Literal["silu", "gelu"] = "silu"
    modality: Modality = "text"
    # parallelism profile: how params/optimizer are sharded over the mesh
    sharding_profile: Literal["dp", "fsdp", "zero3"] = "fsdp"
    remat: Literal["nothing", "dots", "full"] = "full"
    # scan-over-layers unroll factor.  1 lowers a while loop (small HLO, the
    # production setting); the dry-run cost probes set it to n_repeats so
    # XLA cost analysis sees every layer (while bodies are counted once).
    scan_unroll: int = 1
    # python-unroll the attention q-chunk loop too (cost probes only)
    probe_unroll: bool = False
    # §Perf hillclimb 1: explicit expert-axis sharding constraints on the
    # MoE dispatch/combine chain (GSPMD otherwise replicates it over
    # 'model' — measured 5x flop inflation at phi3.5 train_4k).  Off by
    # default so the recorded baseline stays reproducible.
    moe_shard_constraints: bool = False
    # §Perf hillclimb 2: for context-parallel archs (q-heads don't divide
    # the model axis), ALSO shard the attention projections by sequence
    # (Megatron-SP style) instead of replicating them over 'model'.
    attn_seq_proj: bool = False
    # §Perf hillclimb 1.2: re-pin the batch sharding right after the
    # embedding lookup (the fsdp/zero3 table's embed axis occupies 'data'
    # and GSPMD otherwise replicates the batch downstream).  Confirmed a
    # pure win on every measured cell (phi: -64% compute, -85% memory;
    # qwen: -96% collective) — ON by default; the recorded baseline table
    # was taken with False.
    batch_shard_constraint: bool = True
    # default gradient-accumulation microbatches for train shapes (the
    # §Perf memory lever: divides the layer-boundary activation stash)
    train_microbatches: int = 1
    # §Perf hillclimb 1.3: norm in bf16 with f32 statistics (False) instead
    # of a full f32 upcast (True) — the upcast copy lands in the scan stash.
    norm_f32: bool = True
    # §Perf hillclimb 1.5: f32 accumulation for the attention PV einsum
    # (True, default) vs native bf16 (False) — the f32 product is what XLA
    # fuses into the out-projection partial sums, widening the TP
    # all-reduces to f32.
    attn_out_f32: bool = True
    # sub-quadratic mechanism available (SSM/hybrid/sliding-window)?
    subquadratic: bool = False
    # embedding / lm-head tables are padded up to a multiple of this so the
    # vocab dim shards evenly over the 'model' mesh axis (MaxText-style);
    # logits beyond ``vocab_size`` are masked to -inf in the forward pass.
    vocab_pad_multiple: int = 256

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of "
            f"pattern length {len(self.pattern)}"
        )

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts, embedding included."""
        d, hd = self.d_model, self.resolved_head_dim
        total = active = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
            active += self.vocab_size * d
        for spec in self.pattern:
            t = a = 0
            if spec.mixer == "attn":
                qkvo = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                t += qkvo
                a += qkvo
            else:
                m = self.mamba or MambaConfig()
                d_in = m.expand * d
                g = m.n_groups * m.d_state
                nheads = d_in // m.head_dim
                p = d * (2 * d_in + 2 * g + nheads)        # in_proj
                p += (d_in + 2 * g) * m.conv_width          # conv
                p += nheads * 2 + nheads                    # A_log, D, dt_bias
                p += d_in * d                               # out_proj
                t += p
                a += p
            if spec.ffn == "dense":
                f = 3 * d * self.d_ff
                t += f
                a += f
            elif spec.ffn == "moe":
                moe = self.moe
                assert moe is not None
                t += d * moe.n_experts + 3 * d * moe.d_ff * moe.n_experts
                a += d * moe.n_experts + 3 * d * moe.d_ff * moe.top_k
            t += 2 * d  # norms (approx; post-norms negligible)
            a += 2 * d
            total += t * self.n_repeats
            active += a * self.n_repeats
        return total, active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "pure full-attention arch: no sub-quadratic mechanism for 500k "
            "context (skip per brief, DESIGN.md §6.2)"
        )
    return True, ""


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.pattern
    small = dict(
        n_layers=len(pat) if len(pat) > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sharding_profile="dp",
        remat="nothing",
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff=64,
            capacity_factor=2.0,
        )
    if cfg.mamba is not None:
        small["mamba"] = MambaConfig(d_state=16, head_dim=16, chunk=8)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
