"""Public PaLD API.

    from repro.core import pald
    C = pald.cohesion(D)                      # auto method selection
    C = pald.cohesion(D, method="pairwise")   # blocked pairwise (Fig. 5)
    C = pald.cohesion(D, method="triplet")    # block-symmetric (Alg. 2 analogue)
    C = pald.cohesion(D, method="kernel")     # Pallas TPU kernels
    C = pald.cohesion(D, method="dense")      # un-blocked vectorized baseline

Inputs of any size are padded internally to a block multiple with +inf
distances; padded points land outside every local focus and contribute
nothing, so the result restricted to the original n x n is exact.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from . import pairwise as _pairwise
from . import triplet as _triplet

Method = Literal["auto", "dense", "pairwise", "triplet", "kernel"]

__all__ = ["cohesion", "local_depths", "pad_distance_matrix"]


def pad_distance_matrix(D: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    """Pad D to a multiple of ``block`` with +inf off-diagonal, 0 diagonal.

    Padded points are infinitely far from everything: they never enter a real
    pair's local focus (inf < d is false) and every real z is inside a padded
    pair's focus but contributes to padded rows of C only.
    """
    n = D.shape[0]
    m = -(-n // block) * block
    if m == n:
        return D, n
    P = jnp.full((m, m), jnp.inf, D.dtype)
    P = P.at[:n, :n].set(D)
    P = P.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    return P, n


def cohesion(
    D: jnp.ndarray,
    *,
    method: Method = "auto",
    block: int = 128,
    normalize: bool = True,
    z_chunk: int | None = None,
) -> jnp.ndarray:
    """Compute the PaLD cohesion matrix C from a distance matrix D."""
    n = D.shape[0]
    if method == "auto":
        method = "dense" if n <= 256 else "triplet"
    if method == "dense":
        return _pairwise.pald_dense(D, z_chunk=z_chunk, normalize=normalize)
    Dp, n0 = pad_distance_matrix(jnp.asarray(D, jnp.float32), block)
    nv = jnp.asarray(n0) if Dp.shape[0] != n0 else None
    # normalization is applied here (not inside the blocked fns) so the padded
    # size never leaks into the 1/(n-1) factor.
    if method == "pairwise":
        C = _pairwise.pald_blocked(Dp, block=block, n_valid=nv)
    elif method == "triplet":
        C = _triplet.pald_block_symmetric(Dp, block=block, n_valid=nv)
    elif method == "kernel":
        from repro.kernels import ops as _kops

        C = _kops.pald(Dp, block=block, n_valid=nv)
    else:
        raise ValueError(f"unknown method {method!r}")
    C = C[:n0, :n0]
    if normalize:
        C = C / (n0 - 1)
    return C


def local_depths(C: jnp.ndarray) -> jnp.ndarray:
    """l_x = sum_z c_xz (cohesion is *partitioned* local depth)."""
    return jnp.sum(C, axis=1)
