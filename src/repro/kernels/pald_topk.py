"""Pallas streaming top-k neighbor selection: feature tiles in, best-list out.

PR 5's ``knn_from_features`` computes each (row_chunk, n) distance slab and
immediately reduces it with a full-width ``lax.top_k`` — correct, but the
slab still round-trips HBM and the reduction re-scans all n candidates per
row.  This kernel is the streaming form of the same contract: the grid walks
(block, d) x (block_z, d) feature tile pairs (the dataflow of
``kernels/pald_fused.py``), computes each (block, block_z) distance tile
in-register via ``features.dist_tile``, and folds it into a running
(block, kp) best-list held in the output ref — so neither D nor any full
per-row score vector ever exists in HBM.

Selection network
-----------------
Each tile is sorted with a bitonic network over COMPOSITE (value, index)
keys — compare-exchange swaps on ``(v1 > v2) | ((v1 == v2) & (i1 > i2))`` —
then its kp best columns are merged into the incumbent best-list with a
single bitonic merge of the 2*kp concatenation (incumbent ascending ++
candidates descending is bitonic by construction).  Because every real
candidate has a distinct global column index, the composite key is a total
order, which makes the maintained list exactly the first kp entries of the
stable ``lax.top_k`` order on negated distances — the lower-index-first
tie-break of ``core.knn._top_k_rows`` — independent of the tile visit
order.

Masking contract: the self column and every padded row/column (global index
>= ``n_valid``) enter the network as (+inf, INT32_MAX) and therefore lose
to every real candidate; with k <= n-1 real candidates per row they can
never reach the returned k columns of a real row.

TPU alignment: ``kp`` (k rounded up to a power of two, the network width)
is lane-padded to 128 for the output refs off interpret mode; the caller
slices back to k.  ``block_z`` must be a power of two >= kp.

Bitwise scope: the selection machinery above is exact — given tile
distance values it reproduces ``_top_k_rows`` bit-for-bit.  The tile
distances themselves come from ``dist_tile``'s GEMM, whose per-pair
contraction order is fixed by d alone on the TPU MXU but is only
shape-stable on XLA:CPU for SIMD-clean d (e.g. 4, 8); for ragged d the
(block, block_z) tile GEMM can differ from the jnp slab GEMM by 1 ulp.
That is an XLA:CPU property shared by every tiled kernel in this repo
(see tests/test_topk_conformance.py), not a property of this network.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.features import dist_tile

__all__ = ["topk_pallas", "sort_pairs", "merge_pairs", "next_pow2"]

_LANE = 128
_IDX_PAD = np.iinfo(np.int32).max


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    p = 1
    while p < x:
        p *= 2
    return p


def _pairs_gt(v1, i1, v2, i2):
    """Composite strict greater-than on (value, index) keys — the dual of
    the stable lower-index-first tiebreak of ``core.knn._top_k_rows``."""
    return (v1 > v2) | ((v1 == v2) & (i1 > i2))


def _cx_pass(v, i, j: int, k: int | None):
    """One compare-exchange pass at stride ``j`` over the last axis.

    ``k`` is the bitonic sort stage (direction alternates per k-aligned
    run, ascending first); ``k=None`` is the all-ascending merge form.
    The pairing trick: reshape (b, w) -> (b, w/(2j), 2, j) puts partners
    (idx, idx^j) on axis 2, and since 2j divides k the direction bit
    (idx & k) is constant per reshaped row — a static mask, no gathers.
    """
    b, w = v.shape
    q = w // (2 * j)
    v4 = v.reshape(b, q, 2, j)
    i4 = i.reshape(b, q, 2, j)
    lo_v, hi_v = v4[:, :, 0, :], v4[:, :, 1, :]
    lo_i, hi_i = i4[:, :, 0, :], i4[:, :, 1, :]
    swap = _pairs_gt(lo_v, lo_i, hi_v, hi_i)
    if k is not None:
        # direction bit from an in-kernel iota (a host-side numpy mask
        # would be a captured constant, which pallas_call rejects)
        qi = jax.lax.broadcasted_iota(jnp.int32, (1, q, 1), 1)
        asc = ((qi * (2 * j)) // k) % 2 == 0
        swap = jnp.where(asc, swap, ~swap)
    nlo_v = jnp.where(swap, hi_v, lo_v)
    nhi_v = jnp.where(swap, lo_v, hi_v)
    nlo_i = jnp.where(swap, hi_i, lo_i)
    nhi_i = jnp.where(swap, lo_i, hi_i)
    v = jnp.stack([nlo_v, nhi_v], axis=2).reshape(b, w)
    i = jnp.stack([nlo_i, nhi_i], axis=2).reshape(b, w)
    return v, i


def sort_pairs(v, i):
    """Full bitonic sort of (b, w) pairs, ascending by (value, index).

    ``w`` must be a power of two.  log2(w)*(log2(w)+1)/2 vectorized
    compare-exchange passes; equal composite keys only arise between
    padding sentinels, where a swap is a no-op."""
    w = v.shape[-1]
    k = 2
    while k <= w:
        j = k // 2
        while j >= 1:
            v, i = _cx_pass(v, i, j, k)
            j //= 2
        k *= 2
    return v, i


def merge_pairs(v, i):
    """Bitonic merge: (b, w) pairs forming a bitonic sequence -> ascending.

    log2(w) passes.  Used on ``incumbent ++ reversed(candidates)``, which
    is ascending-then-descending and hence bitonic."""
    w = v.shape[-1]
    j = w // 2
    while j >= 1:
        v, i = _cx_pass(v, i, j, None)
        j //= 2
    return v, i


def _topk_kernel(xi_ref, xj_ref, val_ref, idx_ref, *, metric, n_valid,
                 block, block_z, kp, out_w):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, _IDX_PAD)

    roff = pl.program_id(0) * block
    coff = j * block_z
    # loop_d=False: the d-streamed manhattan form accumulates in a
    # different summation order than the slab paths' broadcast-cube sum,
    # which breaks the bitwise-vs-_top_k_rows contract.  The (block,
    # block_z, d) cube lives only for this tile, so VMEM stays bounded.
    dt = dist_tile(xi_ref[...], xj_ref[...], metric,
                   loop_d=False)                         # (block, block_z)
    rows = roff + jax.lax.broadcasted_iota(jnp.int32, (block, block_z), 0)
    cols = coff + jax.lax.broadcasted_iota(jnp.int32, (block, block_z), 1)
    # exclude-self masking: unlike masked_dist_tile's zero diagonal, the
    # selection contract removes x from its own candidate set entirely
    bad = (rows >= n_valid) | (cols >= n_valid) | (rows == cols)
    cv = jnp.where(bad, jnp.inf, dt)
    ci = jnp.where(bad, _IDX_PAD, cols)
    cv, ci = sort_pairs(cv, ci)
    cv, ci = cv[:, :kp], ci[:, :kp]                      # tile's kp best
    iv = val_ref[...][:, :kp]
    ii = idx_ref[...][:, :kp]
    mv = jnp.concatenate([iv, cv[:, ::-1]], axis=1)      # bitonic 2*kp
    mi = jnp.concatenate([ii, ci[:, ::-1]], axis=1)
    mv, mi = merge_pairs(mv, mi)
    mv, mi = mv[:, :kp], mi[:, :kp]
    pad = out_w - kp
    if pad:
        mv = jnp.concatenate(
            [mv, jnp.full((block, pad), jnp.inf, jnp.float32)], axis=1)
        mi = jnp.concatenate(
            [mi, jnp.full((block, pad), _IDX_PAD, jnp.int32)], axis=1)
    val_ref[...] = mv
    idx_ref[...] = mi


@functools.partial(jax.jit, static_argnames=(
    "k", "metric", "n_valid", "block", "block_z", "interpret"))
def topk_pallas(
    X: jnp.ndarray,            # (m, d) zero-padded features
    *,
    k: int,
    metric: str = "euclidean",
    n_valid: int,
    block: int = 128,
    block_z: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming k-nearest selection: (m, d) features -> (m, k) best-lists.

    Returns ``(distances, indices)`` rows sorted ascending by
    (distance, index) — bitwise the rows of ``core.knn._top_k_rows`` on the
    masked distance matrix.  Rows >= ``n_valid`` are junk (+inf / INT32_MAX)
    for the caller to slice off; ``m`` must divide by both ``block`` and
    ``block_z``, and ``block_z`` must be a power of two >= next_pow2(k).
    """
    m, d = X.shape
    kp = next_pow2(max(k, 1))
    assert m % block == 0 and m % block_z == 0, (m, block, block_z)
    assert block_z == next_pow2(block_z) and block_z >= kp, (block_z, kp)
    out_w = kp if interpret else max(-(-kp // _LANE) * _LANE, _LANE)
    kernel = functools.partial(
        _topk_kernel, metric=metric, n_valid=n_valid, block=block,
        block_z=block_z, kp=kp, out_w=out_w)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(m // block, m // block_z),   # col axis last: sequential fold
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),     # rows
            pl.BlockSpec((block_z, d), lambda i, j: (j, 0)),   # candidates
        ],
        out_specs=[
            pl.BlockSpec((block, out_w), lambda i, j: (i, 0)),
            pl.BlockSpec((block, out_w), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, out_w), jnp.float32),
            jax.ShapeDtypeStruct((m, out_w), jnp.int32),
        ],
        interpret=interpret,
    )(X.astype(jnp.float32), X.astype(jnp.float32))
    return vals[:, :k], idx[:, :k]
