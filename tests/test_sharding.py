"""Logical-axis -> PartitionSpec mapping rules and cache shardings."""
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import mesh as meshlib
from repro.sharding import partition

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)


@pytest.fixture(scope="module")
def mesh2d():
    return meshlib.make_test_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module")
def mesh3d():
    return meshlib.make_test_mesh((2, 2, 2), ("pod", "data", "model"))


def test_tensor_axes_map_to_model(mesh2d):
    assert partition.spec_to_pspec(("embed", "ff"), "fsdp", mesh2d) == P("data", "model")
    assert partition.spec_to_pspec(("experts", "embed", None), "fsdp", mesh2d) == \
        P("model", "data", None)
    assert partition.spec_to_pspec(("vocab", "embed"), "dp", mesh2d) == P("model", None)


def test_head_axes_divisibility(mesh2d):
    # model axis = 2: 4 heads shard, 3 heads replicate
    assert partition.spec_to_pspec(("embed", "q_heads", None), "fsdp", mesh2d,
                                   shape=(32, 4, 8)) == P("data", "model", None)
    assert partition.spec_to_pspec(("embed", "q_heads", None), "fsdp", mesh2d,
                                   shape=(32, 3, 8)) == P("data", None, None)
    assert partition.spec_to_pspec(("embed", "kv_heads", None), "fsdp", mesh2d,
                                   shape=(32, 1, 8)) == P("data", None, None)


def test_zero3_uses_all_data_axes(mesh3d):
    spec = partition.spec_to_pspec(("embed", "ff"), "zero3", mesh3d)
    assert spec == P(("pod", "data"), "model")
    spec = partition.spec_to_pspec(("embed", "ff"), "fsdp", mesh3d)
    assert spec == P("data", "model")
    spec = partition.spec_to_pspec(("embed", "ff"), "dp", mesh3d)
    assert spec == P(None, "model")


def test_batch_pspec(mesh3d):
    assert partition.batch_pspec(mesh3d, 8) == P(("pod", "data"))
    # batch 3 divides neither axis -> unsharded
    assert partition.batch_pspec(mesh3d, 3) == P(None)
    # batch 2 divides pod only
    assert partition.batch_pspec(mesh3d, 2) == P(("pod",))


def test_param_shardings_tree(mesh2d):
    from repro.models import layers as L
    import jax.numpy as jnp
    cfg = configs.reduced(configs.get("llama3.2-3b"))
    params, specs = L.init_attention(jax.random.PRNGKey(0), cfg)
    sh = partition.param_shardings(specs, "fsdp", mesh2d, params)
    # reduced cfg: n_heads=4 divides model=2 -> q_heads sharded
    assert sh["wq"].spec == P("data", "model", None)
    # n_kv_heads=2 divides 2 as well
    assert sh["wk"].spec == P("data", "model", None)
    assert sh["wo"].spec == P("model", None, "data")


def test_seq_shard_constraint(mesh2d):
    import jax.numpy as jnp
    x = jnp.zeros((2, 8, 4))
    with mesh2d:
        y = jax.jit(lambda t: partition.seq_shard(t, 1))(x)
    assert y.sharding.spec[1] == "model"
    # indivisible dim: no-op (no crash)
    x2 = jnp.zeros((2, 7, 4))
    with mesh2d:
        y2 = jax.jit(lambda t: partition.seq_shard(t, 1))(x2)


def test_cache_shardings(mesh2d):
    from repro.train import serve_step
    cfg = configs.reduced(configs.get("gemma2-2b"))
    sh = serve_step.cache_shardings(cfg, mesh2d, batch=4, max_len=64)
    assert len(sh) == len(cfg.pattern)
    for layer_sh in sh:
        assert "k" in layer_sh and "v" in layer_sh
