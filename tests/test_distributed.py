"""Distributed PaLD under shard_map on a fake 8-device mesh vs reference."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distributed, reference
from repro.launch import mesh as meshlib

from conftest import euclidean_distance_matrix

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)


def _ref(D):
    return reference.pald_pairwise_reference(D, ties="ignore", normalize=True)


@pytest.fixture(scope="module")
def D48():
    rng = np.random.default_rng(7)
    return euclidean_distance_matrix(rng.normal(size=(48, 4)))


@pytest.fixture(scope="module")
def D50():
    # NOT divisible by any mesh size -> exercises the padding path
    rng = np.random.default_rng(8)
    return euclidean_distance_matrix(rng.normal(size=(50, 4)))


@pytest.mark.parametrize("strategy", ["allgather", "ring"])
def test_1d_strategies(D48, strategy):
    mesh = meshlib.make_test_mesh((8,), ("data",))
    C = np.asarray(distributed.pald_distributed(D48, mesh, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,axes", [
    ((4, 2), ("data", "model")),
    ((2, 4), ("data", "model")),
    ((2, 2, 2), ("pod", "data", "model")),
])
def test_2d_strategy(D48, shape, axes):
    mesh = meshlib.make_test_mesh(shape, axes)
    C = np.asarray(distributed.pald_distributed(D48, mesh, strategy="2d", impl="jnp"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


def test_2d_pod_stream_equals_full_gather(D48):
    """The hierarchical pod-streamed schedule must be numerically identical
    to the plain 2-D schedule (it only changes data movement)."""
    mesh = meshlib.make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    C1 = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", pod_stream=False, impl="jnp"))
    C2 = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", pod_stream=True, impl="jnp"))
    np.testing.assert_allclose(C2, _ref(D48), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(C1, C2, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("strategy", ["ring", "2d"])
def test_padding_path(D50, strategy):
    mesh = (meshlib.make_test_mesh((8,), ("data",)) if strategy == "ring"
            else meshlib.make_test_mesh((4, 2), ("data", "model")))
    C = np.asarray(distributed.pald_distributed(D50, mesh, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, _ref(D50), rtol=1e-5, atol=1e-6)


def test_interpret_kernels_under_shard_map(D48):
    """Per-device compute routed through the Pallas kernels (interpret)."""
    mesh = meshlib.make_test_mesh((2, 2), ("data", "model"))
    C = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", impl="interpret"))
    np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


def test_bf16_comm_dtype(D48):
    """bf16 distance communication (§Perf 3): exact whenever no two
    distances collide in the same bf16 ulp (generic random data)."""
    import jax.numpy as jnp
    mesh = meshlib.make_test_mesh((4, 2), ("data", "model"))
    C = np.asarray(distributed.pald_distributed(
        D48, mesh, strategy="2d", impl="jnp", comm_dtype=jnp.bfloat16))
    # bf16 rounding perturbs the order of near-equal distances only; on
    # generic data the cohesion matrix stays close to fp32
    assert np.abs(C - _ref(D48)).max() < 5e-3
    assert abs(C.sum() - 24.0) < 0.1   # mass ~ n/2 preserved


def test_auto_strategy(D48):
    mesh1 = meshlib.make_test_mesh((8,), ("data",))
    mesh2 = meshlib.make_test_mesh((4, 2), ("data", "model"))
    for mesh in (mesh1, mesh2):
        C = np.asarray(distributed.pald_distributed(D48, mesh, impl="jnp"))
        np.testing.assert_allclose(C, _ref(D48), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# feature-sharded strategies: X row-sharded, distances derived on-device
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def X50():
    rng = np.random.default_rng(9)
    return rng.normal(size=(50, 4)).astype(np.float32)  # 50: padding path


@pytest.mark.parametrize("strategy", ["allgather", "ring"])
@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_from_features_strategies(X50, strategy, metric):
    from repro.core import features, pald

    mesh = meshlib.make_test_mesh((8,), ("data",))
    Cref = np.asarray(pald.cohesion(
        features.cdist_reference(X50, metric=metric), method="dense"))
    C = np.asarray(distributed.pald_distributed_from_features(
        jnp.asarray(X50), mesh, metric=metric, strategy=strategy, impl="jnp"))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_from_features_multi_axis_mesh_flattens(X50):
    from repro.core import features, pald

    mesh = meshlib.make_test_mesh((4, 2), ("data", "model"))
    Cref = np.asarray(pald.cohesion(
        features.cdist_reference(X50, metric="euclidean"), method="dense"))
    C = np.asarray(distributed.pald_distributed_from_features(
        jnp.asarray(X50), mesh, impl="jnp"))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_from_features_rejects_unknown_strategy(X50):
    mesh = meshlib.make_test_mesh((8,), ("data",))
    with pytest.raises(ValueError):
        distributed.pald_distributed_from_features(
            jnp.asarray(X50), mesh, strategy="2d")
