"""Sparse k-NN PaLD vs the best dense path: the n x k sweep (ISSUE 5),
plus the selection-stage n x k x d sweep (ISSUE 9).

Each n gets one row for the measured-best dense path (``pald.plan`` with
``method="auto"`` — the tuning-cache crossover pick) and one row per k for
``method="knn"``.  The knn timing is the full API cost: neighbor
selection + sparse cohesion + dense scatter, so the speedup column is
what a caller switching ``method=`` actually observes.

Dense cost grows O(n^3); at the largest n each dense cell is measured
with a single post-warmup run (``iters=1``) to keep the --fast suite
bounded, which is noisier but the gap measured here is orders of
magnitude, not percent.

``run_selection`` (ISSUE 9) times the neighbor-selection stage itself
and the fused select->cohere pipeline, per (n, k, d) cell:

* ``chunked``      — the terminal degradation rung: host-driven row
                     slabs, each a jitted dist-slab -> masked
                     ``lax.top_k``.  The baseline everything else is
                     scored against.
* ``jnp-direct``   — one ``lax.map`` scan of jitted slabs, full-width
                     top_k (``tile >= n``).
* ``jnp-tilemin``  — same scan with the exact tile-min prefilter
                     (rank k tiles by per-tile distance minima, gather,
                     then top_k over k*tile columns).
* ``interpret``    — the streaming Pallas kernel under ``interpret=True``
                     (CPU emulation; only measured at small n — it
                     exists here to track the kernel's dataflow, the
                     compiled path needs an accelerator backend).
* ``two-stage``    — ``topk_select`` then ``knn_values``: the unfused
                     pipeline a caller composes by hand.
* ``fused``        — ``select_cohere``: selection tiles handed straight
                     to the cohesion tile body, no NeighborGraph
                     round-trip between stages.

All selection variants are bitwise-identical in output (enforced by
tests/test_topk_conformance.py), so every speedup here is free.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pald

from .common import random_distance_matrix, time_fn


def run(ns=(1024, 4096), ks=(16, 32, 64), iters: int = 2) -> list[dict]:
    rows: list[dict] = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        it = 1 if n >= 4096 else iters
        p = pald.plan(D)
        t_dense = time_fn(lambda: p.execute(D), iters=it)
        rows.append({"n": n, "k": "-", "method": f"dense/{p.method}",
                     "seconds": round(t_dense, 4), "speedup_vs_dense": 1.0})
        for k in ks:
            if k > n - 1:
                continue
            pk = pald.plan(D, method="knn", k=k)
            t = time_fn(lambda: pk.execute(D), iters=max(it, 2))
            rows.append({"n": n, "k": k, "method": "knn",
                         "seconds": round(t, 4),
                         "speedup_vs_dense": round(t_dense / t, 1)})
    return rows


def run_selection(cells=((1024, 16, 8), (4096, 32, 8), (4096, 32, 4)),
                  iters: int = 3, interpret_max_n: int = 512,
                  tile: int = 32) -> list[dict]:
    """Selection-stage + fused-pipeline timings per (n, k, d) cell."""
    from repro.kernels import ops
    from repro.tuning.autotune import random_features

    rows: list[dict] = []
    for n, k, d in cells:
        X = jnp.asarray(random_features(n, d=d))
        it = 1 if n >= 8192 else iters

        def cell(variant, seconds, base=None):
            rows.append({
                "n": n, "k": k, "d": d, "variant": variant,
                "seconds": round(seconds, 4),
                "speedup_vs_chunked":
                    round(base / seconds, 2) if base else 1.0,
            })
            return seconds

        t0 = cell("chunked", time_fn(
            lambda: ops.topk_select(X, k, impl="chunked").distances,
            iters=it))
        cell("jnp-direct", time_fn(
            lambda: ops.topk_select(X, k, impl="jnp", tile=n).distances,
            iters=it), t0)
        cell("jnp-tilemin", time_fn(
            lambda: ops.topk_select(X, k, impl="jnp",
                                    tile=min(tile, n)).distances,
            iters=it), t0)
        if n <= interpret_max_n:
            cell("interpret", time_fn(
                lambda: ops.topk_select(X, k, impl="interpret").distances,
                iters=1), t0)

        # pipeline cost: unfused two-stage vs the fused executor path
        def two_stage():
            g = ops.topk_select(X, k)
            return ops.knn_values(X, g, kind="features")

        t2 = cell("two-stage", time_fn(two_stage, iters=it), t0)
        tf = time_fn(lambda: ops.select_cohere(X, k=k)[1], iters=it)
        rows.append({
            "n": n, "k": k, "d": d, "variant": "fused",
            "seconds": round(tf, 4),
            "speedup_vs_chunked": round(t0 / tf, 2) if tf else 0.0,
        })
        rows[-1]["speedup_vs_two_stage"] = round(t2 / tf, 2) if tf else 0.0
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run(), header="knn: sparse k-NN PaLD vs best dense path")
    emit(run_selection(),
         header="selection: streaming top-k + fused select->cohere")
