"""Feature-space PaLD: distances from vectors, fused or materialized.

Every real workload starts from feature vectors, not a distance matrix —
yet the classic pipeline materializes the full O(n^2) ``D`` in HBM before
pass 1, exactly the kind of avoidable data movement the paper's blocking
analysis (W = Theta(n^3/sqrt(M))) warns about.  This module is the feature
front-end:

``cdist_reference(X, Y=None, metric=...)``
    Plain-jnp pairwise distances for the supported metrics.  The oracle the
    fused kernels are tested against, and the "materialize-then-PaLD" path.

The public entry point lives in ``repro.core.pald.from_features`` — a thin
facade over the execution-plan engine (``core/engine.py``), which resolves
``method="fused"`` (distance tiles computed on the fly from ``(block, d)``
feature tiles inside the kernel, so ``D`` never hits HBM — DESIGN.md §10)
vs. the materialize-once paths, and owns the batched ``(B, n, d)`` layer.

Supported metrics (see ``METRICS``): ``sqeuclidean``, ``euclidean``,
``cosine``, ``manhattan``.  All distance computation is float32; inputs of
any float dtype are cast exactly once at the executor boundary (float64
inputs are explicitly, not silently, downcast).

Tile-level building blocks (``dist_tile``, ``masked_dist_tile``) are shared
by the Pallas kernels (``repro.kernels.pald_fused``), the jnp fused
fallback (``repro.kernels.ops``), and the feature-sharded distributed
strategies (``repro.core.distributed``), so every path computes bit-wise
comparable distances.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

METRICS = ("sqeuclidean", "euclidean", "cosine", "manhattan")

Metric = Literal["sqeuclidean", "euclidean", "cosine", "manhattan"]

_NORM_EPS = 1e-30  # cosine guard: zero vectors get distance 1, not nan

__all__ = [
    "METRICS",
    "cdist_reference",
    "dist_tile",
    "masked_dist_tile",
    "pad_features",
]


# ---------------------------------------------------------------------------
# tile-level distance computation (usable inside Pallas kernel bodies)
# ---------------------------------------------------------------------------
def dist_tile(XA: jnp.ndarray, XB: jnp.ndarray, metric: str,
              *, loop_d: bool = False) -> jnp.ndarray:
    """(ma, d) x (mb, d) -> (ma, mb) distances, float32.

    ``loop_d=True`` streams the feature axis with a fori_loop instead of
    materializing the (ma, mb, d) broadcast cube — the manhattan form the
    Pallas kernels use so VMEM stays at tile size.  Zero-padded feature
    columns are exact no-ops for every metric (they add 0 to dots, norms
    and absolute differences), which is what lets the kernels pad d up to
    the TPU lane quantum.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r} (expected one of {METRICS})")
    XA = XA.astype(jnp.float32)
    XB = XB.astype(jnp.float32)
    if metric in ("sqeuclidean", "euclidean"):
        na = jnp.sum(XA * XA, axis=1, keepdims=True)            # (ma, 1)
        nb = jnp.sum(XB * XB, axis=1, keepdims=True)            # (mb, 1)
        dot = jax.lax.dot_general(
            XA, XB, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        d2 = jnp.maximum(na + nb.T - 2.0 * dot, 0.0)
        return jnp.sqrt(d2) if metric == "euclidean" else d2
    if metric == "cosine":
        na = jnp.sqrt(jnp.maximum(jnp.sum(XA * XA, axis=1, keepdims=True),
                                  _NORM_EPS))
        nb = jnp.sqrt(jnp.maximum(jnp.sum(XB * XB, axis=1, keepdims=True),
                                  _NORM_EPS))
        dot = jax.lax.dot_general(
            XA, XB, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return 1.0 - dot / (na * nb.T)
    # manhattan
    if loop_d:
        d = XA.shape[1]

        def body(j, acc):
            ca = jax.lax.dynamic_slice_in_dim(XA, j, 1, axis=1)  # (ma, 1)
            cb = jax.lax.dynamic_slice_in_dim(XB, j, 1, axis=1)  # (mb, 1)
            return acc + jnp.abs(ca - cb.T)

        return jax.lax.fori_loop(
            0, d, body, jnp.zeros((XA.shape[0], XB.shape[0]), jnp.float32)
        )
    return jnp.sum(jnp.abs(XA[:, None, :] - XB[None, :, :]), axis=-1)


def masked_dist_tile(XA: jnp.ndarray, XB: jnp.ndarray, metric: str,
                     row_off, col_off, n_valid: int,
                     *, loop_d: bool = False) -> jnp.ndarray:
    """Distance tile with the padding contract of ``pad_distance_matrix``
    applied in-register: rows/cols at global index >= n_valid are +inf
    (padded points are infinitely far from everything) and the exact global
    diagonal is 0 (fp noise in ``d(x, x)`` must not break the "x is always
    in its own focus" invariant)."""
    D = dist_tile(XA, XB, metric, loop_d=loop_d)
    ma, mb = D.shape
    rows = row_off + jax.lax.broadcasted_iota(jnp.int32, (ma, mb), 0)
    cols = col_off + jax.lax.broadcasted_iota(jnp.int32, (ma, mb), 1)
    D = jnp.where((rows >= n_valid) | (cols >= n_valid), jnp.inf, D)
    return jnp.where(rows == cols, 0.0, D)


# ---------------------------------------------------------------------------
# materialized reference distances
# ---------------------------------------------------------------------------
def cdist_reference(X: jnp.ndarray, Y: jnp.ndarray | None = None,
                    *, metric: Metric = "euclidean") -> jnp.ndarray:
    """Pairwise distances in plain jnp, float32.

    With ``Y=None`` the square form zeroes its diagonal exactly (the
    dot-product formulation of d(x, x) is only zero up to fp noise), so it
    composes with ``pald.cohesion`` without spurious self-distances.
    """
    from .resilience import fault_point

    fault_point("features.cdist", metric=metric)
    X = jnp.asarray(X, jnp.float32)
    square = Y is None
    Y = X if square else jnp.asarray(Y, jnp.float32)
    D = dist_tile(X, Y, metric)
    if square:
        n = X.shape[0]
        D = D.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    return D


def pad_features(X: jnp.ndarray, quantum: int) -> tuple[jnp.ndarray, int]:
    """Pad rows of X up to a multiple of ``quantum`` with zero vectors.

    Unlike distance-matrix padding, the +inf semantics can't be expressed in
    feature space; the fused kernels re-impose them per tile via
    ``masked_dist_tile(n_valid=...)``.  Returns (padded X, original n).
    """
    n = X.shape[0]
    m = -(-n // quantum) * quantum
    if m == n:
        return X, n
    return jnp.pad(X, ((0, m - n), (0, 0))), n


# The public entry point (``pald.from_features``) and the batched layer live
# in ``repro.core.pald`` / ``repro.core.engine``; this module provides the
# metric tile primitives every executor shares.
