"""Shared benchmark utilities: timing, CSV emit, distance-matrix makers."""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def random_distance_matrix(n: int, seed: int = 0, dim: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)).astype(np.float32)
    np.fill_diagonal(D, 0.0)
    return D


def emit(rows: list[dict], header: str = "") -> None:
    if header:
        print(f"# {header}")
    if not rows:
        return
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    print()
