"""jit'd wrappers around the PaLD Pallas kernels.

On TPU the kernels lower to Mosaic; on CPU (this container) either
``interpret=True`` Pallas execution (bit-faithful to the kernel body, used by
tests) or a vectorized jnp fallback with identical semantics (used for speed
in distributed CPU runs) is selected via ``impl=``.

The *general* (rectangular) forms are the primitives that both the sequential
square algorithm and the shard_map distributed algorithms call per device:

    focus_general(DXZ, DYZ, DXY)        -> U (mx, my)
    cohesion_general(DXZ, DYZ, DXY, W)  -> C (mx, mz)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .pald_cohesion import cohesion_general_pallas, cohesion_pallas  # noqa: F401
from .pald_focus import focus_general_pallas, focus_pallas  # noqa: F401
from .pald_focus_tri import focus_tri_pallas  # noqa: F401
from .ref import weights_ref

__all__ = [
    "pald",
    "focus",
    "cohesion_from_weights",
    "focus_general",
    "cohesion_general",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _default_impl() -> str:
    return "pallas" if on_tpu() else "jnp"


def _pick_block(m: int, want: int) -> int:
    """Largest divisor of m that is <= want (block shapes must tile exactly)."""
    b = min(want, m)
    while m % b:
        b -= 1
    return b


# --------------------------------------------------------------------------
# jnp fallback with identical semantics to the kernels (z/y-chunked).
# --------------------------------------------------------------------------
# The fallback materializes an (mx, my, chunk) comparison cube per step; at
# production block sizes (6400x6400 on the 2-D distributed schedule) a fixed
# 512-chunk is a 20 GiB buffer.  Cap the bool cube at 512 MiB instead (its
# f32-cast sibling in the cohesion einsum is then <= 2 GiB) — the chunk
# adapts down as blocks grow (PaLD §Perf iteration).
_CUBE_BUDGET = 512 << 20


def _adaptive_chunk(mx: int, my: int, mz: int, want: int) -> int:
    cap = max(_CUBE_BUDGET // max(mx * my, 1), 8)
    return _pick_block(mz, min(want, cap))


@functools.partial(jax.jit, static_argnames=("chunk",))
def _focus_general_jnp(DXZ, DYZ, DXY, *, chunk: int = 512):
    mx, mz = DXZ.shape
    c = _adaptive_chunk(mx, DYZ.shape[0], mz, chunk)

    def body(acc, blks):
        dxz, dyz = blks  # (mx, c), (my, c)
        m = (dxz[:, None, :] < DXY[:, :, None]) | (dyz[None, :, :] < DXY[:, :, None])
        return acc + jnp.sum(m, axis=-1, dtype=jnp.float32), None

    xs = (
        DXZ.reshape(mx, mz // c, c).transpose(1, 0, 2),
        DYZ.reshape(DYZ.shape[0], mz // c, c).transpose(1, 0, 2),
    )
    U, _ = jax.lax.scan(body, jnp.zeros(DXY.shape, jnp.float32), xs)
    return U


@functools.partial(jax.jit, static_argnames=("chunk",))
def _cohesion_general_jnp(DXZ, DYZ, DXY, W, *, chunk: int = 128):
    my = DYZ.shape[0]
    mx, mz = DXZ.shape
    c = _adaptive_chunk(mx, mz, my, chunk)

    def body(acc, blks):
        dyz, dxy, w = blks  # (c, mz), (mx, c), (mx, c)
        g = (DXZ[:, None, :] < dyz[None, :, :]) & (DXZ[:, None, :] < dxy[:, :, None])
        return acc + jnp.einsum("xyz,xy->xz", g.astype(jnp.float32), w), None

    xs = (
        DYZ.reshape(my // c, c, -1),
        DXY.reshape(DXY.shape[0], my // c, c).transpose(1, 0, 2),
        W.reshape(W.shape[0], my // c, c).transpose(1, 0, 2),
    )
    C, _ = jax.lax.scan(body, jnp.zeros((DXZ.shape[0], DXZ.shape[1]), jnp.float32), xs)
    return C


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------
def focus_general(DXZ, DYZ, DXY, *, block: int = 128, block_z: int = 512, impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "jnp":
        return _focus_general_jnp(DXZ, DYZ, DXY, chunk=block_z)
    bx = _pick_block(DXZ.shape[0], block)
    by = _pick_block(DYZ.shape[0], block)
    bz = _pick_block(DXZ.shape[1], block_z)
    return focus_general_pallas(
        DXZ, DYZ, DXY, block_x=bx, block_y=by, block_z=bz, interpret=impl == "interpret"
    )


def cohesion_general(DXZ, DYZ, DXY, W, *, block: int = 128, block_z: int = 512, impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "jnp":
        return _cohesion_general_jnp(DXZ, DYZ, DXY, W, chunk=block)
    bx = _pick_block(DXZ.shape[0], block)
    by = _pick_block(DYZ.shape[0], block)
    bz = _pick_block(DXZ.shape[1], block_z)
    return cohesion_general_pallas(
        DXZ, DYZ, DXY, W, block_x=bx, block_y=by, block_z=bz, interpret=impl == "interpret"
    )


def focus(D, *, block: int = 128, block_z: int = 512, impl: str | None = None,
          schedule: str = "dense"):
    """schedule='tri' uses the upper-triangular scalar-prefetch kernel
    (pald_focus_tri): ~half the comparisons of the dense grid, same
    result.  Only meaningful for the square (sequential) case."""
    if schedule == "tri":
        impl = impl or ("pallas" if on_tpu() else "interpret")
        if impl in ("pallas", "interpret"):
            b = _pick_block(D.shape[0], block)
            bz = _pick_block(D.shape[0], block_z)
            return focus_tri_pallas(
                D, block=b, block_z=bz, interpret=impl == "interpret"
            )
    return focus_general(D, D, D, block=block, block_z=block_z, impl=impl)


def cohesion_from_weights(D, W, *, block: int = 128, block_z: int = 512, impl: str | None = None):
    return cohesion_general(D, D, D, W, block=block, block_z=block_z, impl=impl)


def pald(
    D,
    *,
    block: int = 128,
    block_z: int = 512,
    normalize: bool = False,
    n_valid=None,
    impl: str | None = None,
):
    """Full PaLD via the kernel pipeline (input padded to block multiples).

    impl: 'pallas' (TPU), 'interpret' (CPU bit-faithful kernel execution),
    'jnp' (vectorized fallback), or None for backend default.
    """
    impl = impl or ("pallas" if on_tpu() else "interpret")
    U = focus(D, block=block, block_z=block_z, impl=impl)
    W = weights_ref(U, n_valid)
    C = cohesion_from_weights(D, W, block=block, block_z=block_z, impl=impl)
    if normalize:
        C = C / (D.shape[0] - 1)
    return C
