"""Paper Appendix C analogue: PaLD on graph shortest-path distances.

The paper runs the OpenMP pairwise algorithm on SNAP collaboration networks
(ca-GrQc 5242, ca-HepPh 12008, ca-CondMat 23133) with all-pairs shortest
path distances.  No network access here, so we synthesize collaboration-
network-like graphs (Watts-Strogatz small worlds with planted cliques),
compute APSP with networkx, and run the same pipeline: distances -> PaLD ->
strong-tie communities, sequential vs distributed.
"""
from __future__ import annotations

import time

import networkx as nx
import numpy as np

import jax

from repro.core import analysis, distributed, pald
from repro.launch import mesh as meshlib

from .common import emit


def collaboration_graph(n: int = 1024, seed: int = 0) -> np.ndarray:
    """Small-world graph + planted cliques; returns APSP distance matrix."""
    rng = np.random.default_rng(seed)
    G = nx.connected_watts_strogatz_graph(n, k=8, p=0.08, seed=seed)
    # planted "research groups": extra cliques of size 5-12
    for _ in range(n // 64):
        mem = rng.choice(n, size=rng.integers(5, 13), replace=False)
        G.add_edges_from((int(a), int(b)) for i, a in enumerate(mem)
                         for b in mem[i + 1:])
    D = np.full((n, n), np.inf, np.float32)
    for src, lengths in nx.all_pairs_shortest_path_length(G):
        for dst, d in lengths.items():
            D[src, dst] = d
    np.fill_diagonal(D, 0.0)
    assert np.isfinite(D).all(), "graph must be connected"
    return D


def run(ns=(512, 1024)) -> list[dict]:
    rows = []
    ndev = len(jax.devices())
    mesh = meshlib.make_test_mesh((ndev,), ("data",))
    for n in ns:
        t0 = time.perf_counter()
        D = collaboration_graph(n)
        t_apsp = time.perf_counter() - t0

        t0 = time.perf_counter()
        C = np.asarray(pald.cohesion(D, method="triplet", block=min(256, n)))
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        Cd = np.asarray(distributed.pald_distributed(D, mesh, strategy="ring",
                                                     impl="jnp"))
        t_par = time.perf_counter() - t0
        assert np.allclose(C, Cd, atol=1e-5)

        # graph distances are small integers -> massive exact ties; the
        # optimized paths drop ties (paper semantics), so communities are
        # conservative
        comms = [c for c in analysis.communities(C) if len(c) > 1]
        rows.append({
            "n": n,
            "apsp_s": round(t_apsp, 2),
            "pald_seq_s": round(t_seq, 3),
            f"pald_p{ndev}_s": round(t_par, 3),
            "speedup": round(t_seq / t_par, 2),
            "communities": len(comms),
            "largest": max((len(c) for c in comms), default=0),
        })
    return rows


def main() -> None:
    emit(run(), header="appendixC: PaLD on graph APSP distances (synthetic collaboration nets)")


if __name__ == "__main__":
    main()
