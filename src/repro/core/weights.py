"""Pluggable cohesion weight functionals — ONE contribution algebra.

PaLD's two passes are parameterized by two pointwise weights (DESIGN.md
§14): a pass-1 FOCUS weight (how strongly third point z belongs to the
(x, y) conflict focus) and a pass-2 SUPPORT weight (with what share z
backs the "own" point of the pair).  *Generalized partitioned local
depth* (Berenhaut, Foley & Lyu 2023, arXiv:2303.10167) shows the whole
algorithm family is exactly this pair of functionals varied — the three
historical ``ties=`` modes are three members of the family, not special
cases of the kernels.

This module is the single seam: a :class:`WeightFunctional` bundles the
two callables plus declared algebraic properties, a registry names the
instances, and every tile body in the repository (blocked jnp, all
Pallas kernels and their fallbacks, the knn tile, the distributed shard
bodies, the reference oracles in ``kernels/ref.py``) calls the two
dispatchers :func:`focus_weight` / :func:`support_weight` below.  A new
functional therefore works on every (method, schedule, impl) cell with
ZERO kernel forks: the functional rides the same hashable static
argument slots the ``ties`` string used to ride (``static_argnames`` on
the jit'd entry points, ``functools.partial`` into Pallas kernel
bodies), so each kernel trace specializes on the functional's closed
expressions exactly as it specialized on the string branch before.

The contract, for a pair (x, y) and third point z:

``focus(dxz, dyz, dxy) -> float32``
    membership weight of z in the (x, y) focus; summed over z into U.
``support(d_own, d_other, d_pair, own_wins=None) -> float32``
    z's contribution to the OWN point of the pair — for the x role
    ``(d_own, d_other, d_pair) = (d_xz, d_yz, d_xy)``, the y role swaps
    own/other.  Multiplied by W = 1/U and accumulated into C.
    ``own_wins`` is the global-index tiebreak (x index > partner index),
    only inspected when ``needs_index_tiebreak`` is declared.
``share(d_own, d_other) -> float32``  (optional)
    declared factoring for mass-conserving families whose support is
    the focus weight split between the two roles: when set,
    ``where(isnan(s), 0, s)`` with ``s = share(a, b) * focus(a, b, c)``
    is bitwise-equal to ``support(a, b, c)`` on EVERY input (padding
    included).  Bodies that already hold the focus cube for the same
    (own, other, pair) triples — the fused knn tile — use it to skip
    evaluating a second smooth cube.

Both callables must be trace-safe inside Pallas tile bodies: jnp
elementwise ops only, broadcasting like the comparisons they replace,
and EXACT zeros on +inf-padded operands (padded points must stay
outside every focus — the nan-guards in the smooth families below exist
precisely because ``inf - inf`` is nan).

Declared properties, consumed by the engine and the test suite:

``needs_index_tiebreak``
    the support weight inspects ``own_wins``; gates every piece of
    xwins plumbing (per-tile iota masks in the kernels, explicit
    ``xwins`` operands on the rectangular/distributed forms).  The
    other functionals short-circuit all of it.
``conserves_mass``
    every pair with a nonempty focus distributes exactly total weight 1
    (so sum(C) == n(n-1)/2 un-normalized) on any input with positive
    off-diagonal distances.  The hypothesis mass law quantifies over
    every registered functional declaring this.
``is_strict``
    both weights are 0/1 indicators, so U is an integer count.

Built-ins (bitwise-identical to the pre-refactor ``ties=`` branches):
``drop``, ``split``, ``ignore``.  New families: :func:`soft_threshold`
(sigmoid focus/support with temperature, recovering ``split`` in the
tau -> 0 limit) and :func:`kernelized` (strict focus, Gaussian-kernel
support shares).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax.numpy as jnp

TIE_MODES = ("drop", "split", "ignore")
DEFAULT_TIES = "drop"

__all__ = [
    "TIE_MODES", "DEFAULT_TIES", "WeightFunctional", "register_weight",
    "registered_weights", "resolve_weight", "validate_ties",
    "focus_weight", "support_weight", "index_xwins",
    "soft_threshold", "kernelized", "DROP", "SPLIT", "IGNORE",
]


@dataclasses.dataclass(frozen=True)
class WeightFunctional:
    """One member of the generalized-PaLD family (module docstring).

    Frozen and hashable, so an instance can ride every ``ties=`` static
    argument slot (jit ``static_argnames``, ``functools.partial`` into
    Pallas kernel bodies) — each kernel trace specializes on the
    instance exactly as it used to specialize on the mode string.
    Parametrized families memoize their factories so equal parameters
    return the SAME instance and jit caches stay warm.
    """

    name: str
    focus: Callable = dataclasses.field(compare=False)
    support: Callable = dataclasses.field(compare=False)
    share: Callable | None = dataclasses.field(default=None, compare=False)
    needs_index_tiebreak: bool = False
    conserves_mass: bool = False
    is_strict: bool = False

    def properties(self) -> dict:
        """The declared-property dict ``plan.explain()`` reports."""
        return {
            "name": self.name,
            "needs_index_tiebreak": self.needs_index_tiebreak,
            "conserves_mass": self.conserves_mass,
            "is_strict": self.is_strict,
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, WeightFunctional] = {}


def register_weight(w: WeightFunctional,
                    overwrite: bool = False) -> WeightFunctional:
    """Register ``w`` under its name so ``weight="<name>"`` resolves to it
    (and so it appears in knob-validation error messages)."""
    if not overwrite and w.name in _REGISTRY and _REGISTRY[w.name] is not w:
        raise ValueError(f"weight functional {w.name!r} already registered")
    _REGISTRY[w.name] = w
    return w


def registered_weights() -> tuple:
    """Sorted names of every registered weight functional."""
    return tuple(sorted(_REGISTRY))


def resolve_weight(weight) -> WeightFunctional:
    """Resolve a ``weight=`` / ``ties=`` spec to a ``WeightFunctional``.

    Accepts an instance (returned unchanged), a registered name, or
    ``None`` (the default functional, ``drop``).  Unknown names raise a
    ``ValueError`` enumerating every REGISTERED functional — including
    user-registered ones — not a hardcoded mode tuple.
    """
    if weight is None:
        return _REGISTRY[DEFAULT_TIES]
    if isinstance(weight, WeightFunctional):
        return weight
    try:
        return _REGISTRY[weight]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown weight functional {weight!r} "
            f"(registered: {registered_weights()})") from None


def validate_ties(ties) -> str:
    """Validate a ``ties=`` mode (sugar for the three built-ins).

    Kept name-compatible with the pre-refactor helper; the error text
    enumerates the registered functionals reachable via ``weight=`` so
    user-registered families are discoverable from the message.
    """
    if isinstance(ties, WeightFunctional):
        ties = ties.name
    if ties not in TIE_MODES:
        raise ValueError(
            f"unknown ties mode {ties!r} (expected one of {TIE_MODES}; "
            f"for the full family use weight= with one of "
            f"{registered_weights()})")
    return ties


# ---------------------------------------------------------------------------
# the three built-ins — bodies are the exact pre-refactor jnp expressions,
# so built-in results are BITWISE identical through the new layer
# ---------------------------------------------------------------------------
def _focus_strict(dxz, dyz, dxy):
    return ((dxz < dxy) | (dyz < dxy)).astype(jnp.float32)


def _focus_split(dxz, dyz, dxy):
    strict = (dxz < dxy) | (dyz < dxy)
    eq = (dxz == dxy) | (dyz == dxy)
    return jnp.where(strict, 1.0, jnp.where(eq, 0.5, 0.0)).astype(jnp.float32)


def _support_drop(d_own, d_other, d_pair, own_wins=None):
    lt = d_own < d_other
    memb = d_own < d_pair
    return (lt & memb).astype(jnp.float32)


def _support_ignore(d_own, d_other, d_pair, own_wins=None):
    if own_wins is None:
        raise ValueError("ties='ignore' needs own_wins (index tiebreak)")
    lt = d_own < d_other
    memb = d_own < d_pair
    return ((lt | ((d_own == d_other) & own_wins)) & memb).astype(jnp.float32)


def _support_split(d_own, d_other, d_pair, own_wins=None):
    # share of the own-vs-other comparison times the half-step membership
    # in the own-vs-pair comparison; the max-membership factor collapses
    # to the role's own comparison (if x gets any share, d_xz <= d_yz)
    lt = d_own < d_other
    memb = d_own < d_pair
    share = lt.astype(jnp.float32) + 0.5 * (d_own == d_other).astype(jnp.float32)
    half = memb.astype(jnp.float32) + 0.5 * (d_own == d_pair).astype(jnp.float32)
    return share * half


DROP = register_weight(WeightFunctional(
    "drop", _focus_strict, _support_drop, is_strict=True))
SPLIT = register_weight(WeightFunctional(
    "split", _focus_split, _support_split, conserves_mass=True))
IGNORE = register_weight(WeightFunctional(
    "ignore", _focus_strict, _support_ignore,
    needs_index_tiebreak=True, conserves_mass=True, is_strict=True))


# ---------------------------------------------------------------------------
# dispatchers — the two names every tile body in the repository calls.
# ``ties`` may be a mode string, a registered name, or a functional.
# ---------------------------------------------------------------------------
def focus_weight(dxz, dyz, dxy, ties=DEFAULT_TIES):
    """Pass-1 membership weight of z in the (x, y) local focus."""
    return resolve_weight(ties).focus(dxz, dyz, dxy)


def support_weight(d_own, d_other, d_pair, ties=DEFAULT_TIES, own_wins=None):
    """Pass-2 weight with which z supports the 'own' point of a pair."""
    return resolve_weight(ties).support(d_own, d_other, d_pair, own_wins)


def index_xwins(row_off, nrows: int, col_off, ncols: int) -> jnp.ndarray:
    """(nrows, ncols) boolean 'global x index > global y index' tiebreak —
    THE definition of the index convention behind ``needs_index_tiebreak``
    functionals (``ties='ignore'``), shared by the blocked square paths
    (offsets = block coordinates x tile) and the distributed bodies
    (offsets = device row offsets, possibly traced).  Always derived
    per-tile from offsets; there is deliberately no dense (n, n) form."""
    rows = row_off + jnp.arange(nrows)
    cols = col_off + jnp.arange(ncols)
    return rows[:, None] > cols[None, :]


# ---------------------------------------------------------------------------
# new families
# ---------------------------------------------------------------------------
def _sigmoid(x):
    """Smoothstep sigmoid: ``0.5 + x*(0.5 - |x|/8)`` on ``clip(x, -2, 2)``.

    An S-curve with the logistic's fixed points (0.5 at 0, rails at
    saturation) built from clip/abs/mul/add only — no transcendental
    and, unlike rational forms such as ``x/(1+|x|)``, no division,
    which is the multi-cycle op on CPU and TPU VPUs (this is what keeps
    the soft functional inside the <= 15%-over-drop benchmark gate);
    every op is available in every Pallas lowering.  On the clamped
    domain the quadratic is C^1 and monotone (slope ``0.5 - |x|/4 >=
    0``) and meets the rails with zero slope, so no outer clip is
    needed.  Saturation is EXACT: ``0.5 + 2*(0.5 - 0.25)`` is 1.0
    bitwise (all dyadic), so any |x| >= 2 lands on exactly 1.0 / 0.0 —
    the tau -> 0 split-recovery guarantee rides on this.  +-inf
    operands (padding) hit the clamp, not an inf/inf = nan; nan inputs
    propagate for the caller's guard.
    """
    x = jnp.clip(x, -2.0, 2.0)
    return 0.5 + x * (0.5 - 0.125 * jnp.abs(x))


def _safe_unit(diff, inv, tie=0.5):
    """sigmoid(diff * inv) with the inf - inf = nan case pinned to ``tie``.

    Padded operands are +inf; their differences are nan exactly when both
    sides are padded, and the membership factor is an exact 0 there, so
    pinning the share to the tie value keeps every product finite and the
    padded contribution exactly zero.
    """
    s = _sigmoid(diff * inv)
    return jnp.where(jnp.isnan(diff), jnp.float32(tie), s)


@functools.lru_cache(maxsize=None)
def soft_threshold(tau: float = 0.1) -> WeightFunctional:
    """Sigmoid focus/support with temperature ``tau``.

    Focus membership is ``mu = sigmoid((d_pair - min(d_xz, d_yz)) /
    tau)`` — one sigmoid of the closer contestant's margin against the
    pair distance; that membership IS the soft threshold the family is
    named for.  z's support for the own point is ``s * mu`` where the
    share ``s`` ramps linearly from 0 to 1 over the ``+-2*tau`` band of
    ``d_other - d_own`` (a hard sigmoid: ``clip(0.5 + (d_other - d_own)
    / (4*tau), 0, 1)``).  The x and y shares sum to 1 (clip-symmetric),
    so the two supports sum to the focus weight and every pair
    distributes total mass 1: ``conserves_mass`` holds on ANY input
    (U > 0 always).  As tau -> 0 both factors harden to the half-step,
    recovering the ``split`` built-in exactly — case by case: the closer
    contestant's min reproduces split's or-of-comparisons focus, the
    share its 0.5-per-tie vote (asserted in tests/test_weights.py).

    This factoring is the cheap form: one smoothstep sigmoid per tile
    body (see ``_sigmoid``; the ramp share is mul/add/clip) versus 3
    sigmoids in pass 1 + 2 in pass 2 for the naive share-weighted
    ``s*mu_x + (1-s)*mu_y``.  benchmarks/BENCH_PR8.json 'weights'
    section gates the cost at <= 15% over strict 'drop'.

    Memoized on tau: equal temperatures return the same instance, so jit
    caches keyed on the functional stay warm.
    """
    # python float, not a jnp scalar: a closure-captured concrete array
    # would be a "captured constant" Pallas refuses to trace
    inv = 1.0 / float(tau)

    # quarter = 1/(4*tau): the ramp share hits its clip rails at
    # |d_other - d_own| = 2*tau, and clip(+-inf) / clip(0.5) are exact,
    # so the tau -> 0 split recovery is bitwise just like the sigmoid's
    # saturation
    quarter = 0.25 * inv

    def focus(dxz, dyz, dxy):
        return _safe_unit(dxy - jnp.minimum(dxz, dyz), inv, tie=0.0)

    def share(d_own, d_other):
        return jnp.clip(0.5 + (d_other - d_own) * quarter, 0.0, 1.0)

    def support(d_own, d_other, d_pair, own_wins=None):
        memb = _sigmoid((d_pair - jnp.minimum(d_own, d_other)) * inv)
        # one guard on the product instead of one per factor: every nan
        # source (inf - inf on padded operands) wants an exact-zero
        # support, because the padded membership is an exact 0 there
        res = share(d_own, d_other) * memb
        return jnp.where(jnp.isnan(res), 0.0, res)

    name = "soft" if float(tau) == 0.1 else f"soft@{float(tau):g}"
    return WeightFunctional(name, focus, support, share=share,
                            conserves_mass=True)


@functools.lru_cache(maxsize=None)
def kernelized(gamma: float = 1.0) -> WeightFunctional:
    """Strict focus, Gaussian-kernelized support shares.

    Membership stays the strict indicator (same expression as ``drop``),
    but an in-focus z splits its vote by relative kernel similarity:
    ``share = K(d_own) / (K(d_own) + K(d_other))`` with ``K(d) =
    exp(-d^2 / gamma^2)`` — algebraically ``sigmoid((d_other^2 -
    d_own^2) / gamma^2)``, computed in that stable form.  A barely-closer
    z no longer casts a full vote (robust support, after the generalized
    PaLD family), and exact ties split 0.5/0.5 without any index
    tiebreak.  Mass is NOT conserved: the share leaks to the out-of-focus
    role like ``drop``.  Memoized on gamma.
    """
    inv = 1.0 / (float(gamma) * float(gamma))  # python float (Pallas-safe)

    def support(d_own, d_other, d_pair, own_wins=None):
        memb = d_own < d_pair
        share = _safe_unit(d_other * d_other - d_own * d_own, inv)
        return jnp.where(memb, share, 0.0).astype(jnp.float32)

    name = ("kernelized" if float(gamma) == 1.0
            else f"kernelized@{float(gamma):g}")
    return WeightFunctional(name, _focus_strict, support)


register_weight(soft_threshold())
register_weight(kernelized())
