"""Unified ``ties=`` contract: regression, properties, and the bf16 path.

PR 3's bug class: on tie-heavy distances the pipeline used to return three
different cohesion matrices for the same input depending on dispatch —
``method="dense"`` implemented ``ties='drop'``, the tri schedules implemented
'ignore' for cross-block pairs but 'drop' inside diagonal blocks (so they
matched *neither* reference), and ``method="auto"`` silently picked among
them by size.  These tests pin the unified contract:

* the 12-point integer-matrix repro is a committed regression test for the
  tri-schedule disagreement (every schedule now matches every mode's
  reference on it);
* ``comm_dtype=bfloat16`` manufactures ties f32 didn't have; the distributed
  result must equal single-device PaLD on the bf16-cast matrix under the
  same explicit ``ties=``;
* the mode-level mass laws: 'split' conserves total mass n/2 on ANY input,
  'ignore' conserves it for positive off-diagonal distances, 'drop' can
  only lose mass.

The guarded hypothesis strategy drawing matrices WITH ties lives in
``test_ties_properties.py`` (own module, so its importorskip cannot take
these deterministic regression tests down with it).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distributed, features, pald, reference
from repro.core.ties import TIE_MODES
from repro.kernels import ops


# ---------------------------------------------------------------------------
# the committed 12-point integer-matrix repro (ISSUE 3).  Ties abound: only
# 5 distinct off-diagonal values for 66 pairs.  Block 8 < n = 12 gives the
# tri schedules both diagonal-block and cross-block pair visits — the two
# code paths whose tie semantics used to disagree.
# ---------------------------------------------------------------------------
def _integer_repro() -> np.ndarray:
    rng = np.random.default_rng(42)
    A = rng.integers(1, 6, size=(12, 12))
    D = np.triu(A, 1)
    return (D + D.T).astype(np.float64)


@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_tri_schedule_integer_repro(ties, impl):
    """Regression: the tri kernels disagreed with the ties='ignore' reference
    they documented (max |dC| ~ 3e-2 before the shared-helper fix)."""
    D = _integer_repro()
    Cref = reference.pald_pairwise_reference(D, ties=ties, normalize=False)
    C = np.asarray(ops.pald_tri(jnp.asarray(D), block=8, block_z=8,
                                impl=impl, ties=ties))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ties", TIE_MODES)
def test_integer_repro_all_paths_agree(ties):
    """One answer per input: every dispatch returns the same matrix."""
    D = _integer_repro()
    Cs = [np.asarray(pald.cohesion(jnp.asarray(D), method=m, schedule=s,
                                   block=8, ties=ties))
          for m, s in (("dense", "dense"), ("pairwise", "dense"),
                       ("triplet", "dense"), ("kernel", "dense"),
                       ("kernel", "tri"))]
    for C in Cs[1:]:
        np.testing.assert_allclose(C, Cs[0], rtol=1e-6, atol=1e-7)


def test_modes_actually_differ_on_ties():
    """The repro matrix distinguishes the three modes (guards against a
    helper refactor that silently collapses them)."""
    D = _integer_repro()
    C = {t: reference.pald_pairwise_reference(D, ties=t) for t in TIE_MODES}
    assert np.abs(C["drop"] - C["ignore"]).max() > 1e-3
    assert np.abs(C["drop"] - C["split"]).max() > 1e-3
    assert np.abs(C["split"] - C["ignore"]).max() > 1e-3


def test_focus_split_is_fractional():
    """'split' weights boundary ties 0.5 in pass 1; U stays a multiple of
    0.5 and is >= the strict count everywhere."""
    D = _integer_repro()
    Us = reference.local_focus_reference(D, ties="split")
    U = reference.local_focus_reference(D, ties="drop")
    assert np.all(Us >= U)
    assert np.abs(Us * 2 - np.round(Us * 2)).max() == 0.0
    assert np.abs(Us - U).max() > 0  # integer distances do produce boundary ties
    # off-diagonal comparison only: the reference documents its diagonal as
    # "left at 0, never used", while the vectorized pass computes the (also
    # never used — W zeroes it) d_xx == d_xx = 0 boundary weight there
    off = ~np.eye(len(D), dtype=bool)
    Uops = np.asarray(ops.focus(jnp.asarray(D), block=8, block_z=8,
                                impl="jnp", ties="split"))
    np.testing.assert_allclose(Uops[off], Us[off], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# mode-level mass laws (exact, on ANY input)
# ---------------------------------------------------------------------------
def _total_mass(D, ties):
    return reference.pald_pairwise_reference(D, ties=ties).sum()


def test_mass_laws_on_tied_input():
    D = _integer_repro()
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    # split: every pair has u > 0 (x, y weigh >= 0.5 each) and distributes
    # exactly 1 -> total mass == number of pairs, always
    assert abs(_total_mass(D, "split") - pairs) < 1e-9
    # ignore: every in-focus z awards its full 1/u to exactly one point, so
    # mass is conserved whenever all off-diagonal distances are positive
    assert abs(_total_mass(D, "ignore") - pairs) < 1e-9
    # drop: tied support evaporates — strictly less mass on this input
    assert _total_mass(D, "drop") < pairs - 1e-3


def test_split_mass_survives_duplicates():
    """Exact duplicates (d_xy = 0) kill strict pairs entirely ('ignore'
    loses their mass); 'split' still distributes each pair's unit."""
    D = _integer_repro()
    D[0, 1] = D[1, 0] = 0.0  # points 0 and 1 are duplicates
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    assert abs(_total_mass(D, "split") - pairs) < 1e-9
    assert _total_mass(D, "ignore") < pairs - 0.5
    # and the optimized paths implement the same law
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="kernel",
                                 schedule="tri", block=8, ties="split",
                                 normalize=False))
    assert abs(C.sum() - pairs) < 1e-3


# ---------------------------------------------------------------------------
# validation: one contract, loudly enforced at every entry point
# ---------------------------------------------------------------------------
def test_unknown_ties_rejected_everywhere():
    D = jnp.zeros((4, 4))
    X = jnp.zeros((4, 2))
    with pytest.raises(ValueError):
        pald.cohesion(D, ties="round-robin")
    with pytest.raises(ValueError):
        pald.from_features(X, ties="round-robin")
    with pytest.raises(ValueError):
        ops.pald(D, ties="round-robin")
    with pytest.raises(ValueError):
        reference.pald_pairwise_reference(np.zeros((4, 4)), ties="round-robin")


def test_rectangular_ignore_needs_xwins():
    D = jnp.asarray(_integer_repro())
    W = jnp.ones((12, 12))
    with pytest.raises(ValueError):
        ops.cohesion_general(D, D, D, W, impl="jnp", ties="ignore")


# ---------------------------------------------------------------------------
# distributed: explicit ties + the bf16 manufactured-ties contract
# ---------------------------------------------------------------------------
needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)


@needs_devices
@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("strategy", ["allgather", "ring", "2d"])
def test_distributed_tie_modes(ties, strategy):
    from repro.launch import mesh as meshlib

    D = _integer_repro()
    Cref = reference.pald_pairwise_reference(D, ties=ties, normalize=True)
    mesh = (meshlib.make_test_mesh((4, 2), ("data", "model"))
            if strategy == "2d" else meshlib.make_test_mesh((8,), ("data",)))
    C = np.asarray(distributed.pald_distributed(
        D, mesh, strategy=strategy, impl="jnp", ties=ties))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@needs_devices
@pytest.mark.parametrize("ties", TIE_MODES)
def test_bf16_comm_equals_single_device_on_cast_matrix(ties):
    """bf16 communication rounds near-equal distances into EXACT ties; with
    the tie mode explicit, the distributed result equals single-device PaLD
    on the bf16-cast matrix under the same ``ties=`` — it no longer depends
    on which kernel the shard body dispatches to."""
    from conftest import euclidean_distance_matrix
    from repro.launch import mesh as meshlib

    rng = np.random.default_rng(7)
    D = euclidean_distance_matrix(rng.normal(size=(48, 4)))
    Dbf = np.asarray(jnp.asarray(D, jnp.bfloat16).astype(jnp.float32),
                     np.float64)
    # the cast must actually manufacture ties, else this test is vacuous
    iu = np.triu_indices(48, 1)
    assert len(np.unique(Dbf[iu])) < len(np.unique(D[iu]))

    mesh = meshlib.make_test_mesh((4, 2), ("data", "model"))
    C = np.asarray(distributed.pald_distributed(
        D, mesh, strategy="2d", impl="jnp", comm_dtype=jnp.bfloat16,
        ties=ties))
    Csingle = np.asarray(pald.cohesion(jnp.asarray(Dbf), method="dense",
                                       ties=ties))
    np.testing.assert_allclose(C, Csingle, rtol=1e-5, atol=1e-6)
    Cref = reference.pald_pairwise_reference(Dbf, ties=ties, normalize=True)
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_fused_quantized_embeddings_all_modes():
    """Quantized (integer-valued) embeddings with duplicated rows: exact
    zero-distance ties through the fused pipeline, all modes."""
    rng = np.random.default_rng(5)
    base = rng.integers(-3, 4, size=(10, 3)).astype(np.float32)
    X = np.vstack([base, base[:4]])  # 4 exact duplicates
    D = np.asarray(features.cdist_reference(X, metric="sqeuclidean"),
                   np.float64)
    for ties in TIE_MODES:
        Cref = reference.pald_pairwise_reference(D, ties=ties, normalize=True)
        C = np.asarray(pald.from_features(jnp.asarray(X), metric="sqeuclidean",
                                          block=8, block_z=8, ties=ties))
        np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)
