"""Shared benchmark utilities: timing, CSV emit, distance-matrix makers.

The timing discipline and synthetic-matrix construction are shared with the
block-size autotuner so tuner and benchmark numbers stay comparable — both
live in ``repro.tuning.autotune`` and are re-exported here.
"""
from __future__ import annotations

from repro.tuning.autotune import random_distance_matrix, time_fn  # noqa: F401


def emit(rows: list[dict], header: str = "") -> None:
    if header:
        print(f"# {header}")
    if not rows:
        return
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    print()
