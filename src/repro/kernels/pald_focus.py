"""Pallas TPU kernel for PaLD pass 1: local-focus sizes.

    U[x, y] = sum_z (D[x,z] < D[x,y]) | (D[y,z] < D[x,y])

Grid (nx, ny, nz) with the z-reduction innermost, so the output block
U[X, Y] stays resident in VMEM across all z steps (Pallas revisiting rule),
exactly like a blocked-matmul accumulator — the TPU analogue of the paper's
"U_XY remains in fast memory through the pass" (Theorem 4.1 proof).

Inside the kernel we iterate the y dimension with a fori_loop over rows so
the live working set is (bx, bz) vectors instead of a (bx, by, bz) cube:
VMEM = D_XZ + D_YZ + D_XY + U_XY = 2*bx*bz + bx*by + bx*by floats.
With bx=by=128, bz=512 that is ~0.66 MiB, well under ~16 MiB VMEM, and all
tile shapes are (8,128)-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.weights import DEFAULT_TIES, focus_weight, resolve_weight

__all__ = ["focus_pallas"]


def _focus_kernel(dxz_ref, dyz_ref, dxy_ref, u_ref, *, ties):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    dxz = dxz_ref[...]  # (bx, bz)
    dyz = dyz_ref[...]  # (by, bz)
    dxy = dxy_ref[...]  # (bx, by)
    by = dxy.shape[1]

    def body(y, acc):
        # column y of the U block: sum_z focus_weight(d_xz, d_yz[y], d_xy[:,y])
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)      # (bx, 1)
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)      # (1, bz)
        m = focus_weight(dxz, row, thr, ties)                      # (bx, bz)
        col = jnp.sum(m, axis=1, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(acc, col, y, axis=1)

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(u_ref))
    u_ref[...] += add


@functools.partial(jax.jit, static_argnames=("block_x", "block_y", "block_z",
                                             "interpret", "ties"))
def focus_general_pallas(
    DXZ: jnp.ndarray,  # (mx, mz) distances x -> z
    DYZ: jnp.ndarray,  # (my, mz) distances y -> z
    DXY: jnp.ndarray,  # (mx, my) distances x -> y
    *,
    block_x: int = 128,
    block_y: int = 128,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """U (mx, my) = sum_z focus_weight(DXZ[x,z], DYZ[y,z], DXY[x,y]) for the
    resolved weight functional (strict membership shown above).

    The rectangular form is what the distributed (shard_map) algorithms call
    per device, with DXZ/DYZ being locally-owned / gathered row blocks.  The
    sequential square case passes the same matrix three times.
    """
    ties = resolve_weight(ties)
    mx, mz = DXZ.shape
    my = DYZ.shape[0]
    assert DYZ.shape[1] == mz and DXY.shape == (mx, my)
    assert mx % block_x == 0 and my % block_y == 0 and mz % block_z == 0
    grid = (mx // block_x, my // block_y, mz // block_z)
    return pl.pallas_call(
        functools.partial(_focus_kernel, ties=ties),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, k)),  # DXZ
            pl.BlockSpec((block_y, block_z), lambda i, j, k: (j, k)),  # DYZ
            pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, j)),  # DXY
        ],
        out_specs=pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mx, my), jnp.float32),
        interpret=interpret,
    )(DXZ.astype(jnp.float32), DYZ.astype(jnp.float32), DXY.astype(jnp.float32))


def focus_pallas(
    D: jnp.ndarray,
    *,
    block_xy: int = 128,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """Square local-focus size matrix (sequential case)."""
    return focus_general_pallas(
        D, D, D, block_x=block_xy, block_y=block_xy, block_z=block_z,
        interpret=interpret, ties=ties
    )
