"""PaLD core: the paper's contribution as a composable JAX module."""
from . import analysis, features, pairwise, pald, reference, triplet  # noqa: F401
from .features import cdist_reference, from_features  # noqa: F401
from .pald import cohesion, local_depths  # noqa: F401
