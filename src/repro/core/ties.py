"""Compatibility shim over the weight-functional subsystem.

The tie-handling predicates that used to live here are now the three
built-in members of the pluggable weight-functional family in
``core/weights.py`` (DESIGN.md §14): ``focus_weight`` / ``support_weight``
dispatch on a mode string, a registered functional name, or a
``WeightFunctional`` instance, and the historical ``ties=`` modes
(``TIE_MODES``) are registered built-ins that bitwise-reproduce the
pre-refactor expressions.  Import from ``repro.core.weights`` in new
code; this module only re-exports the stable names.

``square_xwins`` is gone on purpose: the dense (n, n) index-tiebreak it
materialized is always derivable per-tile from ``index_xwins`` offsets,
and every call site now does exactly that.
"""
from __future__ import annotations

from .weights import (  # noqa: F401
    DEFAULT_TIES,
    TIE_MODES,
    WeightFunctional,
    focus_weight,
    index_xwins,
    resolve_weight,
    support_weight,
    validate_ties,
)

__all__ = ["TIE_MODES", "DEFAULT_TIES", "WeightFunctional", "validate_ties",
           "focus_weight", "support_weight", "index_xwins", "resolve_weight"]
