"""Fused features→PaLD kernel sweeps (interpret mode) vs the jnp oracles.

The fused kernels recompute distance tiles in-register from feature tiles;
these tests pin them against materialize-then-oracle per pass, across
blocks, metrics, and padded shapes — plus the tile-level distance helpers
themselves against scipy-style numpy formulas.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import features
from repro.kernels import ops, ref
from repro.kernels.pald_fused import cohesion_fused_pallas, focus_fused_pallas


def _X(rng, n, d=4):
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def _np_cdist(X, metric):
    X = np.asarray(X, np.float64)
    diff = X[:, None, :] - X[None, :, :]
    if metric == "sqeuclidean":
        D = (diff ** 2).sum(-1)
    elif metric == "euclidean":
        D = np.sqrt((diff ** 2).sum(-1))
    elif metric == "manhattan":
        D = np.abs(diff).sum(-1)
    else:  # cosine
        norm = np.linalg.norm(X, axis=1)
        D = 1.0 - (X @ X.T) / np.outer(norm, norm)
    np.fill_diagonal(D, 0.0)
    return D


@pytest.mark.parametrize("metric", features.METRICS)
def test_cdist_reference_matches_numpy(rng, metric):
    X = _X(rng, 23, 5)
    D = np.asarray(features.cdist_reference(X, metric=metric))
    np.testing.assert_allclose(D, _np_cdist(X, metric), rtol=1e-4, atol=1e-5)
    assert (np.diag(D) == 0).all()
    # loop_d manhattan (the kernel form) agrees with the broadcast cube form
    if metric == "manhattan":
        Dl = np.asarray(features.dist_tile(X, X, metric, loop_d=True))
        np.testing.assert_allclose(
            Dl, np.asarray(features.dist_tile(X, X, metric)),
            rtol=1e-6, atol=1e-6)


def test_masked_dist_tile_padding_contract(rng):
    X = _X(rng, 8, 3)
    Xp = jnp.pad(X, ((0, 4), (0, 0)))       # 4 zero-padded rows
    D = np.asarray(features.masked_dist_tile(Xp, Xp, "euclidean", 0, 0, 8))
    assert np.isinf(D[8:, :8]).all() and np.isinf(D[:8, 8:]).all()
    assert (np.diag(D) == 0).all()           # incl. the padded diagonal
    assert np.isfinite(D[:8, :8]).all()


@pytest.mark.parametrize("n,blk,blkz", [(32, 8, 8), (64, 16, 32), (96, 32, 96)])
@pytest.mark.parametrize("metric", ["sqeuclidean", "manhattan"])
def test_focus_fused_kernel_sweep(rng, n, blk, blkz, metric):
    X = _X(rng, n)
    D = features.cdist_reference(X, metric=metric)
    U = focus_fused_pallas(X, metric=metric, n_valid=n, block=blk,
                           block_z=blkz, interpret=True)
    np.testing.assert_allclose(np.asarray(U), np.asarray(ref.focus_ref(D)))


@pytest.mark.parametrize("n,blk,blkz", [(32, 8, 8), (64, 16, 32), (96, 32, 96)])
@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_cohesion_fused_kernel_sweep(rng, n, blk, blkz, metric):
    X = _X(rng, n)
    D = features.cdist_reference(X, metric=metric)
    W = ref.weights_ref(ref.focus_ref(D))
    C = cohesion_fused_pallas(X, W, metric=metric, n_valid=n, block=blk,
                              block_z=blkz, interpret=True)
    np.testing.assert_allclose(np.asarray(C), np.asarray(ref.cohesion_ref(D, W)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [37, 100])
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_pald_fused_nonmultiple_sizes(rng, n, impl):
    """ops.pald_fused zero-pads feature rows and re-imposes the +inf
    contract per tile; any n must come out exact."""
    X = _X(rng, n)
    D = features.cdist_reference(X, metric="euclidean")
    W = ref.weights_ref(ref.focus_ref(D))
    Cref = np.asarray(ref.cohesion_ref(D, W))
    C = np.asarray(ops.pald_fused(X, metric="euclidean", block=16,
                                  block_z=16, impl=impl))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-5)


def test_pald_fused_jnp_matches_interpret(rng):
    X = _X(rng, 64)
    Cj = ops.pald_fused(X, metric="cosine", block=16, block_z=32, impl="jnp")
    Ci = ops.pald_fused(X, metric="cosine", block=16, block_z=32,
                        impl="interpret")
    np.testing.assert_allclose(np.asarray(Cj), np.asarray(Ci),
                               rtol=1e-6, atol=1e-6)


def test_pald_fused_block_auto_and_tuning_key(tmp_path, rng, monkeypatch):
    """block='auto' resolves through the pald_fused pass keyed by (n, d)."""
    from repro.tuning import autotune

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    X = _X(rng, 48, 4)
    C = ops.pald_fused(X, metric="euclidean", block="auto", impl="jnp")
    D = features.cdist_reference(X, metric="euclidean")
    W = ref.weights_ref(ref.focus_ref(D))
    np.testing.assert_allclose(np.asarray(C), np.asarray(ref.cohesion_ref(D, W)),
                               rtol=1e-5, atol=1e-5)
    # a tuned (n, d) cell is honored; a different d misses it
    autotune.save_entry("cpu", "jnp", 48, "pald_fused:d4",
                        {"block": 24, "block_z": 48, "seconds": 0.1})
    assert autotune.resolve_blocks(48, "pald_fused", impl="jnp",
                                   backend="cpu", d=4) == (24, 48)
    assert autotune.lookup("cpu", "jnp", 48, "pald_fused:d32") is None


def test_tune_pald_fused_roundtrip(tmp_path):
    from repro.tuning import autotune

    cache = str(tmp_path / "tune.json")
    rec = autotune.tune(32, "pald_fused", impl="jnp", blocks=(8, 16),
                        blocks_z=(16,), path=cache, iters=1, d=4)
    assert {"block", "block_z", "seconds", "grid"} <= set(rec)
    got = autotune.resolve_blocks(32, "pald_fused", impl="jnp", path=cache, d=4)
    assert got == (rec["block"], rec["block_z"])
