"""Mamba2 (state-space duality / SSD) mixer block.

Chunked SSD scan for training/prefill (O(L) memory, MXU-friendly block
einsums) and an O(1)-state single-step path for decode.  Heads are sharded
over the ``model`` mesh axis (head-dim groups stay whole per shard); the
B/C group projections (n_groups=1 at the assigned configs) are replicated.

The jamba hybrid uses this same block (DESIGN.md §9: Mamba-1 -> Mamba2
substitution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def init_mamba(key, cfg):
    m = cfg.mamba
    d = cfg.d_model
    d_in = m.expand * d
    H = d_in // m.head_dim
    gn = m.n_groups * m.d_state
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    params = {
        "in_z": jax.random.normal(ks[0], (d, d_in), jnp.float32) * s,
        "in_x": jax.random.normal(ks[1], (d, d_in), jnp.float32) * s,
        "in_B": jax.random.normal(ks[2], (d, gn), jnp.float32) * s,
        "in_C": jax.random.normal(ks[3], (d, gn), jnp.float32) * s,
        "in_dt": jax.random.normal(ks[4], (d, H), jnp.float32) * s,
        "conv_x": jax.random.normal(ks[5], (m.conv_width, d_in), jnp.float32) * 0.1,
        "conv_B": jax.random.normal(ks[6], (m.conv_width, gn), jnp.float32) * 0.1,
        "conv_C": jax.random.normal(ks[7], (m.conv_width, gn), jnp.float32) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out": jax.random.normal(ks[8], (d_in, d), jnp.float32) * d_in ** -0.5,
    }
    specs = {
        "in_z": ("embed", "mamba_inner"),
        "in_x": ("embed", "mamba_inner"),
        "in_B": ("embed", None),
        "in_C": ("embed", None),
        "in_dt": ("embed", "mamba_heads"),
        "conv_x": (None, "mamba_inner"),
        "conv_B": (None, None),
        "conv_C": (None, None),
        "A_log": ("mamba_heads",),
        "D": ("mamba_heads",),
        "dt_bias": ("mamba_heads",),
        "norm": ("mamba_inner",),
        "out": ("mamba_inner", "embed"),
    }
    return params, specs


def _causal_conv(x: Array, w: Array, state: Array | None):
    """Depthwise causal conv.  x: (B, L, C), w: (K, C).
    state: (B, K-1, C) trailing context or None (zero history).
    Returns (y (B, L, C), new_state)."""
    B, L, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, K-1+L, C)
    y = sum(xp[:, i : i + L, :] * w[i] for i in range(K))
    new_state = xp[:, L:, :] if K > 1 else state
    return y, new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0: Array | None):
    """Chunked SSD.  xh: (B,L,H,P), dt: (B,L,H), A: (H,), Bm/Cm: (B,L,G,N).
    Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    B, L, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, L)
    while L % Q:
        Q -= 1
    nc = L // Q

    def to_heads(t):  # (B,L,G,N) -> (B,L,H,N)
        return jnp.repeat(t, hpg, axis=2)

    Bh, Ch = to_heads(Bm), to_heads(Cm)
    a = dt * A  # (B, L, H), negative log-decays
    xr = xh.reshape(B, nc, Q, H, P)
    dtr = dt.reshape(B, nc, Q, H)
    ar = a.reshape(B, nc, Q, H)
    Br = Bh.reshape(B, nc, Q, H, N)
    Cr = Ch.reshape(B, nc, Q, H, N)
    acs = jnp.cumsum(ar, axis=2)  # (B, nc, Q, H)

    # intra-chunk (diagonal) term
    seg = acs[:, :, :, None, :] - acs[:, :, None, :, :]         # (B,nc,Q_i,Q_j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br)               # (B,nc,Q,Q,H)
    xdt = xr * dtr[..., None]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", CB * M, xdt)

    # per-chunk input->state contribution
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs)             # (B,nc,Q,H)
    states = jnp.einsum("bcjhn,bcjhp->bchpn", Br * (decay_to_end * dtr)[..., None], xr)
    chunk_decay = jnp.exp(acs[:, :, -1, :])                     # (B,nc,H)

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_out = h      # state *entering* this chunk
        h = h * dec[:, :, None, None] + st
        return h, h_out

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    h_final, h_enter = jax.lax.scan(step, h0, xs)
    h_enter = jnp.moveaxis(h_enter, 0, 1)                        # (B,nc,H,P,N)
    y_off = jnp.einsum("bcihn,bchpn->bcihp", Cr, h_enter) * jnp.exp(acs)[..., None]

    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, h_final


def mamba_apply(params, cfg, x: Array, *, state: dict | None = None):
    """x: (B, L, d).  state: {"conv_x","conv_B","conv_C","ssm"} or None.
    Returns (y (B, L, d), new_state or None)."""
    m = cfg.mamba
    B, L, d = x.shape
    d_in = m.expand * d
    H = d_in // m.head_dim
    P = m.head_dim
    G, N = m.n_groups, m.d_state

    z = x @ params["in_z"]
    xs = x @ params["in_x"]
    Bm = x @ params["in_B"]
    Cm = x @ params["in_C"]
    dt = jax.nn.softplus(x @ params["in_dt"] + params["dt_bias"])  # (B,L,H)

    st = state or {}
    xs, cs_x = _causal_conv(xs, params["conv_x"], st.get("conv_x"))
    Bm, cs_B = _causal_conv(Bm, params["conv_B"], st.get("conv_B"))
    Cm, cs_C = _causal_conv(Cm, params["conv_C"], st.get("conv_C"))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    A = -jnp.exp(params["A_log"])  # (H,)
    xh = xs.reshape(B, L, H, P).astype(jnp.float32)
    Bh = Bm.reshape(B, L, G, N).astype(jnp.float32)
    Ch = Cm.reshape(B, L, G, N).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    if L > 1:
        # chunked SSD for train and prefill (carries incoming state if any)
        y, h_final = _ssd_chunked(xh, dtf, A, Bh, Ch, m.chunk, st.get("ssm"))
    else:
        # single-step recurrence for decode
        h = st.get("ssm")
        if h is None:
            h = jnp.zeros((B, H, P, N), jnp.float32)

        def step(h, inp):
            xt, dtt, Bt, Ct = inp  # (B,H,P),(B,H),(B,G,N),(B,G,N)
            hpg = H // G
            Bt = jnp.repeat(Bt, hpg, axis=1)  # (B,H,N)
            Ct = jnp.repeat(Ct, hpg, axis=1)
            da = jnp.exp(dtt * A)              # (B,H)
            h = h * da[:, :, None, None] + jnp.einsum(
                "bhp,bhn->bhpn", xt * dtt[..., None], Bt
            )
            y = jnp.einsum("bhn,bhpn->bhp", Ct, h)
            return h, y

        xs_seq = (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
        )
        h_final, ys = jax.lax.scan(step, h, xs_seq)
        y = jnp.moveaxis(ys, 0, 1)  # (B,L,H,P)

    y = y + xh * params["D"][:, None]
    y = y.reshape(B, L, d_in).astype(x.dtype)
    # gated RMSNorm then output projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * params["norm"]).astype(x.dtype)
    out = y @ params["out"]
    new_state = None
    if state is not None:
        new_state = {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C, "ssm": h_final}
    return out, new_state
