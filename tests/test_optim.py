"""AdamW from-scratch implementation vs a straight-line numpy reference."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw


def _np_adamw(cfg, p, g, m, v, step, gnorm):
    scale = min(1.0, cfg.clip_norm / (gnorm + 1e-9))
    g = g * scale
    lr = float(adamw.schedule(cfg, jnp.asarray(step, jnp.float32)))
    t = step + 1.0
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adamw_matches_reference():
    cfg = adamw.AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=100)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    grads = jax.tree.map(lambda p: p * 0.1 + 0.01, params)
    opt = adamw.init(params)

    new_p, new_opt, metrics = adamw.apply(cfg, params, grads, opt, jnp.asarray(0))
    gnorm = float(np.sqrt(sum((np.asarray(g) ** 2).sum() for g in jax.tree.leaves(grads))))
    assert float(metrics["grad_norm"]) == pytest.approx(gnorm, rel=1e-6)
    for k in ("w", "b"):
        ref, _, _ = _np_adamw(
            cfg, np.asarray(params[k]), np.asarray(grads[k]),
            np.zeros_like(params[k]), np.zeros_like(params[k]), 0.0, gnorm,
        )
        np.testing.assert_allclose(np.asarray(new_p[k]), ref, rtol=1e-5, atol=1e-7)


def test_clipping_engages():
    cfg = adamw.AdamWConfig(clip_norm=0.1, warmup_steps=0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    opt = adamw.init(params)
    _, _, metrics = adamw.apply(cfg, params, grads, opt, jnp.asarray(0))
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-5)


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10, total_steps=110)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s, jnp.float32)))
           for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=1e-3)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    # monotone decay after warmup
    post = lrs[2:]
    assert all(a >= b - 1e-9 for a, b in zip(post, post[1:]))


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(adamw.global_norm(t)) == pytest.approx(5.0)
