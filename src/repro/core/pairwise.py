"""Blocked, branch-free pairwise PaLD in pure JAX.

This is the TPU-idiomatic realization of the paper's optimized pairwise
algorithm (Section 5): all branches are replaced by mask arithmetic, and the
computation is blocked so each (X, Y) block pair streams the third-point axis.

Two entry points:

``pald_dense(D)``
    Un-blocked formulation; materializes (n, n, n)-shaped masks in chunks.
    The reference for the blocked/Pallas versions.

``pald_blocked(D, block=...)``
    The paper's blocked loop structure (Fig. 5) expressed with
    ``jax.lax.fori_loop`` over block pairs.  O(b^2 n) temporaries.

Both compute, with W = 1/U (zero diagonal):

    U[x, y] = sum_z focus_weight(D[x,z], D[y,z], D[x,y])
    C[x, z] = sum_y support_weight(D[x,z], D[y,z], D[x,y]) * W[x,y]

with the focus/support contributions supplied by the resolved weight
functional shared across every path (``core/weights.py``);
the default ``ties='drop'`` reduces to the classic strict masks and matches
``reference.pald_pairwise_reference(ties='drop')`` entry-wise on any input
(see tests/test_pald_core.py, tests/test_conformance.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .weights import (DEFAULT_TIES, focus_weight, index_xwins, resolve_weight,
                      support_weight)

__all__ = ["local_focus_dense", "pald_dense", "pald_blocked"]


def local_focus_dense(D: jnp.ndarray, *, z_chunk: int | None = None,
                      ties=DEFAULT_TIES) -> jnp.ndarray:
    """U[x,y] = #{z : d_xz < d_xy or d_yz < d_xy}, computed in z-chunks
    (fractional boundary-tie membership under ``ties='split'``)."""
    D = D.astype(jnp.float32)
    n = D.shape[0]
    z_chunk = z_chunk or n

    def body(carry, Dz):
        # Dz: (zc, n) rows of D for a chunk of z (d_zx == d_xz by symmetry).
        # m[x, y, z] = focus membership weight of z in the (x, y) focus
        dxz = Dz.T  # (n, zc) -> d_xz for x in rows
        m = focus_weight(dxz[:, None, :], dxz[None, :, :], D[:, :, None], ties)
        return carry + jnp.sum(m, axis=-1, dtype=jnp.float32), None

    n_chunks = -(-n // z_chunk)
    pad = n_chunks * z_chunk - n
    Dp = jnp.pad(D, ((0, pad), (0, 0)), constant_values=jnp.inf)
    chunks = Dp.reshape(n_chunks, z_chunk, n)
    U, _ = jax.lax.scan(body, jnp.zeros((n, n), jnp.float32), chunks)
    return U


def _weights(U: jnp.ndarray, n_valid: jnp.ndarray | int | None = None) -> jnp.ndarray:
    """W = 1/U with a zero diagonal (the diagonal is never a valid pair).

    ``n_valid`` zeroes rows/columns of padded points so that a padded partner
    y never contributes 1/u_xy support to a real entry C[x, z].
    """
    n = U.shape[0]
    eye = jnp.eye(n, dtype=bool)
    W = jnp.where(eye | (U == 0), 0.0, 1.0 / jnp.where(U == 0, 1.0, U))
    if n_valid is not None:
        valid = jnp.arange(n) < n_valid
        W = W * valid[:, None] * valid[None, :]
    return W


def pald_dense(
    D: jnp.ndarray, *, z_chunk: int | None = None, normalize: bool = False,
    ties=DEFAULT_TIES
) -> jnp.ndarray:
    """Branch-free dense-pairwise PaLD; O(n^2 * chunk) temporaries."""
    ties = resolve_weight(ties)
    D = D.astype(jnp.float32)
    n = D.shape[0]
    U = local_focus_dense(D, z_chunk=z_chunk, ties=ties)
    W = _weights(U)
    z_chunk_ = z_chunk or n
    # index-tiebreak functionals break support ties by global index (larger
    # index wins); the ordered (x, y) grid visits both orders, so the x-role
    # tiebreak suffices
    xwins = (index_xwins(0, n, 0, n)[:, :, None]
             if ties.needs_index_tiebreak else None)

    def body(_, Dz):
        # C[x, zc] = sum_y support_weight(d_xz, d_yz, d_xy) * W[x, y]
        dxz = Dz.T  # (n, zc)
        g = support_weight(dxz[:, None, :], dxz[None, :, :], D[:, :, None],
                           ties, xwins)
        return None, jnp.einsum("xyz,xy->xz", g, W)

    n_chunks = -(-n // z_chunk_)
    pad = n_chunks * z_chunk_ - n
    Dp = jnp.pad(D, ((0, pad), (0, 0)), constant_values=jnp.inf)
    chunks = Dp.reshape(n_chunks, z_chunk_, n)
    _, C_chunks = jax.lax.scan(body, None, chunks)  # (n_chunks, n, z_chunk)
    C = jnp.moveaxis(C_chunks, 0, 1).reshape(n, n_chunks * z_chunk_)[:, :n]
    if normalize:
        C = C / (n - 1)
    return C


@functools.partial(jax.jit, static_argnames=("block", "normalize", "ties"))
def pald_blocked(
    D: jnp.ndarray,
    *,
    block: int = 128,
    normalize: bool = False,
    n_valid: jnp.ndarray | int | None = None,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """Blocked pairwise PaLD (paper Fig. 5 structure) in pure JAX.

    Iterates over (xb, yb) block pairs of the U/W matrix with a fori_loop and,
    for each pair, streams all n third points at once (the paper's un-blocked
    innermost z loop, optimal for the pairwise variant per Section 4.2).
    n must be padded to a multiple of ``block`` by the caller (`pald` does).
    """
    ties = resolve_weight(ties)
    D = D.astype(jnp.float32)
    n = D.shape[0]
    assert n % block == 0, "caller must pad to a block multiple"
    nb = n // block

    # ---- pass 1: local focus sizes ---------------------------------------
    def focus_block(xb, yb):
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))  # d_xz
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))  # d_yz
        Dxy = jax.lax.dynamic_slice(Dx, (0, yb * block), (block, block))
        m = focus_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None], ties)
        return jnp.sum(m, axis=-1, dtype=jnp.float32)  # (block, block)

    def focus_loop(i, U):
        xb, yb = i // nb, i % nb
        blk = focus_block(xb, yb)
        return jax.lax.dynamic_update_slice(U, blk, (xb * block, yb * block))

    U = jax.lax.fori_loop(0, nb * nb, focus_loop, jnp.zeros((n, n), jnp.float32))
    W = _weights(U, n_valid)

    # ---- pass 2: cohesion -------------------------------------------------
    def coh_block(xb, yb):
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))  # d_xz (bx, n)
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))  # d_yz (by, n)
        Dxy = jax.lax.dynamic_slice(Dx, (0, yb * block), (block, block))
        Wxy = jax.lax.dynamic_slice(W, (xb * block, yb * block), (block, block))
        xw = None
        if ties.needs_index_tiebreak:  # global-index tiebreak (every ordered
            # pair visited, so the x-role form suffices)
            xw = index_xwins(xb * block, block, yb * block, block)[:, :, None]
        g = support_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None],
                           ties, xw)
        return jnp.einsum("xyz,xy->xz", g, Wxy)  # (bx, n)

    def coh_loop(i, C):
        xb, yb = i // nb, i % nb
        add = coh_block(xb, yb)
        row = jax.lax.dynamic_slice(C, (xb * block, 0), (block, n))
        return jax.lax.dynamic_update_slice(C, row + add, (xb * block, 0))

    C = jax.lax.fori_loop(0, nb * nb, coh_loop, jnp.zeros((n, n), jnp.float32))
    if normalize:
        C = C / (n - 1)
    return C


# ---------------------------------------------------------------------------
# engine executors: this module's contributions to the dispatch registry.
# Each receives one unbatched item plus the resolved plan and owns the full
# per-item pipeline (cast, pad, compute, slice, normalize) — see
# core/engine.py.
# ---------------------------------------------------------------------------
from . import engine as _engine  # noqa: E402  (registry import, cycle-free)


@_engine.register_executor("distance", "dense", "dense")
def _exec_dense(D, plan):
    D = jnp.asarray(D, jnp.float32)  # explicit boundary cast
    n = D.shape[0]
    C = pald_dense(D, z_chunk=plan.z_chunk, normalize=False, ties=plan.weight)
    return C / max(n - 1, 1) if plan.normalize else C


@_engine.register_executor("distance", "pairwise", "dense")
def _exec_pairwise(D, plan):
    Dp, n0 = _engine.pad_distance_matrix(D, plan.block)  # f32 boundary cast
    nv = jnp.asarray(n0) if Dp.shape[0] != n0 else None
    # normalization applies to the unpadded extent only, so the padded size
    # never leaks into the 1/(n-1) factor
    C = pald_blocked(Dp, block=plan.block, n_valid=nv, ties=plan.weight)
    C = C[:n0, :n0]
    return C / max(n0 - 1, 1) if plan.normalize else C
