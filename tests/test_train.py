"""Training integration: loss goes down, grad accumulation is exact,
checkpoint restart is bit-faithful, elastic reshard works."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import checkpointer
from repro.configs.base import ModelConfig, reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch import mesh as meshlib
from repro.optim import adamw
from repro.sharding import partition
from repro.train import train_step as ts


TINY = ModelConfig(
    "tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=128, head_dim=8, remat="nothing", sharding_profile="dp",
    vocab_pad_multiple=8,
)


def _data(batch=4, seq=32, vocab=128, seed=0):
    return SyntheticTokens(vocab, seq, batch, seed=seed)


def test_loss_decreases():
    opt = adamw.AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=40)
    step = jax.jit(ts.make_train_step(TINY, opt))
    state, _ = ts.init_state(TINY, jax.random.PRNGKey(0))
    data = _data()
    losses = []
    for i in range(40):
        state, m = step(state, data.batch_at(i % 4))  # small repeating stream
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_grad_accumulation_matches_big_batch():
    opt = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=0, total_steps=10)
    step1 = jax.jit(ts.make_train_step(TINY, opt, microbatches=1))
    step4 = jax.jit(ts.make_train_step(TINY, opt, microbatches=4))
    state, _ = ts.init_state(TINY, jax.random.PRNGKey(1))
    batch = _data(batch=8).batch_at(0)
    s1, m1 = step1(jax.tree.map(jnp.copy, state), batch)
    s4, m4 = step4(jax.tree.map(jnp.copy, state), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    # one AdamW update differs by at most ~lr per element: bf16 reduction
    # order can flip the sign of the normalized step where the gradient is
    # noise-level, so compare against a few lr of slack (lr=1e-3 here)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=4e-3)


def test_checkpoint_restart_exact(tmp_path):
    """Stop at step 5, restore, continue to 10: identical to uninterrupted."""
    opt = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(ts.make_train_step(TINY, opt))
    data = _data()

    state, _ = ts.init_state(TINY, jax.random.PRNGKey(2))
    ref = jax.tree.map(jnp.copy, state)
    for i in range(10):
        ref, _ = step(ref, data.batch_at(i))

    run = jax.tree.map(jnp.copy, state)
    for i in range(5):
        run, _ = step(run, data.batch_at(i))
    checkpointer.save(str(tmp_path), 4, run)

    template = jax.eval_shape(lambda: run)
    restored, at = checkpointer.restore_latest(str(tmp_path), template)
    assert at == 4
    for i in range(5, 10):
        restored, _ = step(restored, data.batch_at(i))

    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_elastic_reshard_restore(tmp_path):
    """A checkpoint written under one mesh restores onto a different mesh."""
    cfg = reduced(configs.get("llama3.2-3b"))
    mesh_a = meshlib.make_test_mesh((4, 2), ("data", "model"))
    mesh_b = meshlib.make_test_mesh((2, 2), ("data", "model"))

    cap = {}

    def build(k):
        state, specs = ts.init_state(cfg, k)
        cap["specs"] = specs
        return state

    with mesh_a:
        abstract = jax.eval_shape(build, jax.random.PRNGKey(0))
        sh_a = partition.param_shardings(
            cap["specs"]["params"], "fsdp", mesh_a, abstract["params"])
        full_a = {"params": sh_a, "opt": {"m": sh_a, "v": sh_a},
                  "step": NamedSharding(mesh_a, P())}
        state = jax.jit(build, out_shardings=full_a)(jax.random.PRNGKey(0))
        checkpointer.save(str(tmp_path), 0, state)

    with mesh_b:
        sh_b = partition.param_shardings(
            cap["specs"]["params"], "fsdp", mesh_b, abstract["params"])
        full_b = {"params": sh_b, "opt": {"m": sh_b, "v": sh_b},
                  "step": NamedSharding(mesh_b, P())}
        restored, at = checkpointer.restore_latest(str(tmp_path), abstract, full_b)
        assert at == 0
        # values identical regardless of mesh
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the new shardings took effect
        leaf = jax.tree.leaves(restored["params"])[0]
        assert leaf.sharding.mesh.shape == dict(mesh_b.shape)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a (2, 2) mesh computes the same loss/update
    as the single-device step."""
    cfg = TINY
    opt = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=0, total_steps=10)
    step = ts.make_train_step(cfg, opt)
    state, _ = ts.init_state(cfg, jax.random.PRNGKey(3))
    batch = _data(batch=8).batch_at(0)

    s_ref, m_ref = jax.jit(step)(jax.tree.map(jnp.copy, state), batch)

    mesh = meshlib.make_test_mesh((2, 2), ("data", "model"))
    with mesh:
        bsh = NamedSharding(mesh, P("data", None))
        sb = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        s_m, m_m = jax.jit(step)(jax.tree.map(jnp.copy, state), sb)
    assert float(m_ref["loss"]) == pytest.approx(float(m_m["loss"]), rel=1e-5)
    # same AdamW near-zero-grad caveat as above: reduction order across the
    # mesh can flip noise-level normalized steps — a few lr of slack
    for a, b in zip(jax.tree.leaves(s_ref["params"]), jax.tree.leaves(s_m["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=4e-3)


def test_train_cli_smoke(tmp_path):
    """The real launcher end-to-end, including checkpoint write + restore."""
    from repro.launch import train as train_cli
    ckpt = str(tmp_path / "ck")
    train_cli.main([
        "--arch", "llama3.2-3b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3",
        "--log-every", "5",
    ])
    assert checkpointer.available_steps(ckpt)
    # restart continues from the checkpoint
    train_cli.main([
        "--arch", "llama3.2-3b", "--smoke", "--steps", "8", "--batch", "4",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3",
        "--log-every", "5",
    ])
