"""Pallas TPU kernels for the PaLD hot spots (focus + cohesion passes)."""
from . import ops, ref  # noqa: F401
