"""Paper Fig. 4 analogue: block-size tuning for the blocked variants.

On TPU the block size is the Pallas BlockSpec tile; on this CPU container we
sweep the same parameter through the pure-jnp blocked implementations (the
kernels' VMEM analysis lives in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.core import pairwise, triplet

from .common import emit, random_distance_matrix, time_fn


def run(n: int = 1024, blocks=(32, 64, 128, 256, 512)) -> list[dict]:
    D = jnp.asarray(random_distance_matrix(n))
    rows = []
    base = {}
    for method, fn in [
        ("pairwise", pairwise.pald_blocked),
        ("triplet", triplet.pald_block_symmetric),
    ]:
        for b in blocks:
            if n % b:
                continue
            t = time_fn(functools.partial(fn, D, block=b))
            base.setdefault(method, t)
            rows.append({
                "method": method, "block": b, "seconds": round(t, 4),
                "speedup_vs_first": round(base[method] / t, 2),
            })
    return rows


def main() -> None:
    emit(run(), header="fig4: block-size tuning (n=1024)")


if __name__ == "__main__":
    main()
