"""Public PaLD API — thin facades over the execution-plan engine.

    from repro.core import pald
    C = pald.cohesion(D)                      # auto method selection
    C = pald.cohesion(D, method="pairwise")   # blocked pairwise (Fig. 5)
    C = pald.cohesion(D, method="triplet")    # block-symmetric (Alg. 2 analogue)
    C = pald.cohesion(D, method="kernel")     # Pallas TPU kernels (dense grid)
    C = pald.cohesion(D, method="kernel",
                      schedule="tri")         # upper-tri kernel pipeline
    C = pald.cohesion(D, method="dense")      # un-blocked vectorized baseline
    C = pald.cohesion(Db)                     # batched: (B, n, n) -> (B, n, n)
    C = pald.from_features(X, metric="cosine")  # fused, from feature vectors

    p = pald.plan(D, method="auto")           # resolve once ...
    C = p.execute(D)                          # ... run (and re-run) anywhere
    p.explain()                               # what resolved, and why

Every knob — auto method via the tuning cache, ``block="auto"`` tiles, impl
defaults, tie semantics, batching — is resolved exactly once by
``pald.plan`` (``core/engine.py``); ``cohesion`` and ``from_features`` are
``plan(...).execute(x)`` with no method branching of their own.  The
executor registry maps each resolved ``(kind, method, schedule)`` cell to a
callable contributed by ``core/pairwise``, ``core/triplet`` and
``kernels/ops`` (DESIGN.md §11).

Inputs of any size are padded internally to a block multiple with +inf
distances; padded points land outside every local focus and contribute
nothing, so the result restricted to the original n x n is exact.

Dtype contract: every entry point casts its input to float32 exactly once
at the executor boundary (float64 inputs are downcast explicitly — PaLD
depends only on the order of distances, which f32 preserves away from ulp
collisions) and always returns float32.

Input contract: the plan layer rejects non-square or wrong-rank ``D`` and
any matrix whose diagonal is not exactly zero (cheap, always on);
``check=True`` additionally verifies finiteness, symmetry and
nonnegativity — worth it at the boundary of a serving path, skipped by
default on the hot path.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from .engine import PaldPlan, pad_distance_matrix  # noqa: F401
from .engine import plan as _engine_plan
from .ties import DEFAULT_TIES, TIE_MODES, validate_ties  # noqa: F401

Method = Literal["auto", "dense", "pairwise", "triplet", "kernel"]
Ties = Literal["drop", "split", "ignore"]

__all__ = ["cohesion", "from_features", "plan", "local_depths",
           "pad_distance_matrix", "PaldPlan"]


def plan(x=None, **kwargs) -> PaldPlan:
    """Resolve a PaLD execution plan once; see ``repro.core.engine.plan``.

    ``pald.plan(D)`` plans the distance pipeline, ``pald.plan(X,
    kind="features", metric=...)`` the feature pipeline; shape-only planning
    (``pald.plan(n=4096)``) works too, for inspection before data exists.
    """
    return _engine_plan(x, **kwargs)


def cohesion(
    D: jnp.ndarray,
    *,
    method: Method = "auto",
    block: int | str | None = None,
    block_z: int | str | None = None,
    schedule: str = "dense",
    normalize: bool = True,
    z_chunk: int | None = None,
    impl: str | None = None,
    ties: Ties = DEFAULT_TIES,
    batch: int | None = None,
    check: bool = False,
) -> jnp.ndarray:
    """Compute the PaLD cohesion matrix C from a distance matrix D.

    D: (n, n) -> C: (n, n), or batched (B, n, n) -> (B, n, n) — every
    method and schedule accepts the batched form; ``batch=`` bounds how many
    items are vmapped per compiled call.

    Methods: "dense" (un-blocked vectorized), "pairwise" (blocked Fig. 5),
    "triplet" (block-symmetric), "kernel" (Pallas pipeline; with
    ``schedule="tri"`` both passes run the upper-triangular block schedule
    — half the block-pair visits), or "auto" (measured crossover).  Feature
    input (no D yet) goes through ``pald.from_features`` instead, whose
    fused method never materializes D at all.
    ``block="auto"`` resolves tiles via the tuning cache (default 128 for
    the blocked paths); ``impl`` selects the kernel backend ('pallas',
    'interpret', 'jnp' — kernel/fused paths only).

    ``ties`` fixes what an exact distance tie means — the SAME answer on
    every method/schedule/impl (DESIGN.md §9):
      'drop'  (default) a tied z supports neither point of the pair; strict
              comparisons everywhere (the paper's "ignore equality" applied
              branch-free) — cheapest, and exact on tie-free input;
      'split' a tie splits support 0.5/0.5 and a z exactly on the focus
              boundary joins with weight 0.5 (the theoretical formulation;
              conserves total cohesion mass on any input);
      'ignore' Algorithm 1's sequential if/else: the higher-index point of
              the pair takes tied support.
    On tie-free distances all three modes return identical results.

    ``check=True`` adds deep input validation (finite, symmetric,
    nonnegative) on top of the always-on shape/zero-diagonal checks.
    """
    p = _engine_plan(
        D, kind="distance", method=method, schedule=schedule, block=block,
        block_z=block_z, z_chunk=z_chunk, normalize=normalize, impl=impl,
        ties=ties, batch=batch, check=check,
    )
    return p.execute(D)


def from_features(
    X: jnp.ndarray,
    *,
    metric: str = "euclidean",
    method: str = "auto",
    batch: int | None = None,
    block: int | str = "auto",
    block_z: int | str | None = None,
    schedule: str = "dense",
    normalize: bool = True,
    impl: str | None = None,
    ties: str = DEFAULT_TIES,
    check: bool = False,
) -> jnp.ndarray:
    """PaLD cohesion straight from feature vectors.

    X: (n, d) -> C: (n, n), or batched (B, n, d) -> (B, n, n).

    method:  "fused" (default via "auto") runs the fused kernel pipeline —
             distance tiles are computed in-register from feature tiles and
             the full D matrix is never materialized in HBM;
             "dense" / "pairwise" / "triplet" / "kernel" materialize D once
             (``cdist_reference``) and run the corresponding distance
             executor.
    metric:  one of ``features.METRICS`` (sqeuclidean, euclidean, cosine,
             manhattan).
    batch:   for 3-D X, how many batch elements to vmap per compiled call
             (None = the whole batch at once); bounds peak memory at
             ``batch * n^2`` floats.
    block:   kernel tile; "auto" consults the tuning cache under the
             ``pald_fused`` pass, keyed by (n, d).
    impl:    kernel backend, kernel/fused methods only ('pallas',
             'interpret', 'jnp'); the pure-jnp blocked paths reject an
             explicit impl rather than silently dropping it.
    ties:    'drop' (default) / 'split' / 'ignore' — what an exact distance
             tie means, identically on every method (see ``pald.cohesion``).
             Quantized or duplicated feature rows produce exact ties in
             every metric, so this matters for real embedding data;
             'split' is the theoretically-faithful choice there.

    Inputs of any float dtype are cast to float32 at the executor boundary —
    float64 feature matrices are downcast explicitly (PaLD only consumes the
    *order* of distances, which f32 preserves for any non-pathological data)
    and the result dtype is always float32.
    """
    p = _engine_plan(
        X, kind="features", metric=metric, method=method, schedule=schedule,
        block=block, block_z=block_z, normalize=normalize, impl=impl,
        ties=ties, batch=batch, check=check,
    )
    return p.execute(X)


def local_depths(C: jnp.ndarray) -> jnp.ndarray:
    """l_x = sum_z c_xz (cohesion is *partitioned* local depth)."""
    return jnp.sum(C, axis=-1)
