"""LM substrate: layers, MoE, Mamba2, generic decoder."""
from . import layers, mamba2, model, moe, transformer  # noqa: F401
