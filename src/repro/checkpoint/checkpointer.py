"""Fault-tolerant checkpointing: atomic save, restore, elastic reshard.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step
            <flat-key>.npy      one file per leaf

Atomicity: leaves are written into ``step_<N>.tmp`` and the directory is
renamed only after the manifest lands — a crash mid-save never corrupts the
latest complete checkpoint.  ``restore_latest`` picks the highest complete
step.  ``AsyncCheckpointer`` snapshots device arrays to host then writes on
a background thread so the train loop is blocked only for the device->host
copy.  On restore, arrays are placed with whatever shardings the *current*
mesh wants — a checkpoint written on 512 chips restores onto any mesh
(elastic scaling); only host memory bounds the reshard.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace(SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name[5:]))
    return sorted(steps)


def restore(
    path: str,
    template: Any,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``template``; optionally place each leaf
    with the given sharding tree (elastic reshard onto the current mesh)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_t:
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        sh = flat_s.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
    # rebuild the tree
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = [out[SEP.join(_path_str(p) for p in path)] for path, _ in leaves_paths]
    return jax.tree_util.tree_unflatten(treedef, vals)


def restore_latest(directory: str, template: Any, shardings=None):
    steps = available_steps(directory)
    if not steps:
        return None, -1
    step = steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    return restore(path, template, shardings), step


def prune(directory: str, keep: int = 3) -> None:
    for step in available_steps(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{step:08d}"))


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.directory, step, host_tree)
            prune(self.directory, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
