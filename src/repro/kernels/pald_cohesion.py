"""Pallas TPU kernel for PaLD pass 2: cohesion accumulation.

    C[x, z] = sum_y (D[x,z] < D[y,z]) & (D[x,z] < D[x,y]) * W[x,y]

with W = 1/U (zero diagonal / padded entries; computed outside the kernel so
the reciprocal is done once — the paper's "precompute reciprocals" trick).

Grid (nx, nz, ny) with the y-reduction innermost: the output block C[X, Z]
stays resident in VMEM across all y steps.  The kernel updates unit-stride
(bx, bz) rows of C — the TPU translation of the paper's "updating columns of
C instead" stride-1 optimization (their C is updated column-wise because the
z loop streams columns; our block layout makes the streamed dim contiguous).

VMEM = D_XZ + C_XZ + D_YZ + D_XY + W_XY = 3*bx*bz + 2*bx*by floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cohesion_pallas"]


def _cohesion_kernel(dxz_ref, dyz_ref, dxy_ref, w_ref, c_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]  # (bx, bz)
    dyz = dyz_ref[...]  # (by, bz)
    dxy = dxy_ref[...]  # (bx, by)
    w = w_ref[...]      # (bx, by)
    by = dxy.shape[1]

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)   # (1, bz)  d_yz
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)   # (bx, 1) d_xy
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)      # (bx, 1)
        g = (dxz < row) & (dxz < thr)                           # (bx, bz)
        return acc + g.astype(jnp.float32) * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


@functools.partial(jax.jit, static_argnames=("block_x", "block_z", "block_y", "interpret"))
def cohesion_general_pallas(
    DXZ: jnp.ndarray,  # (mx, mz)
    DYZ: jnp.ndarray,  # (my, mz)
    DXY: jnp.ndarray,  # (mx, my)
    W: jnp.ndarray,    # (mx, my)
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """C (mx, mz) = sum_y (DXZ < DYZ[y]) & (DXZ < DXY[:,y]) * W[:,y].

    Rectangular form for distributed per-device compute; the square
    sequential case passes D three times.
    """
    mx, mz = DXZ.shape
    my = DYZ.shape[0]
    assert DYZ.shape[1] == mz and DXY.shape == (mx, my) and W.shape == (mx, my)
    assert mx % block_x == 0 and mz % block_z == 0 and my % block_y == 0
    grid = (mx // block_x, mz // block_z, my // block_y)
    return pl.pallas_call(
        _cohesion_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),  # DXZ
            pl.BlockSpec((block_y, block_z), lambda i, j, k: (k, j)),  # DYZ
            pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, k)),  # DXY
            pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, k)),  # W
        ],
        out_specs=pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mx, mz), jnp.float32),
        interpret=interpret,
    )(
        DXZ.astype(jnp.float32),
        DYZ.astype(jnp.float32),
        DXY.astype(jnp.float32),
        W.astype(jnp.float32),
    )


def cohesion_pallas(
    D: jnp.ndarray,
    W: jnp.ndarray,
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Square cohesion matrix (un-normalized, sequential case)."""
    return cohesion_general_pallas(
        D, D, D, W, block_x=block_x, block_z=block_z, block_y=block_y, interpret=interpret
    )
