"""Sparse k-NN PaLD subsystem (core/knn.py, kernels/pald_knn.py, engine).

Covers the ISSUE-5 edge-case checklist: k >= n-1 equals dense bitwise
(and the sparse machinery itself converges to dense), k = 1, duplicated
points under all three ``ties=`` modes, batched (B, n, d) / (B, n, n)
input, plan-validation errors for illegal knob combos, and the
selection/tile contracts (deterministic tie-break, impl bit-faithfulness,
lane-padding masks)."""
import functools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import features, knn, pald
from repro.core.ties import TIE_MODES
from repro.kernels import ops


def _D(n=20, seed=0, d=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return jnp.asarray(D, jnp.float32)


# ---------------------------------------------------------------------------
# entry-wise numpy reference of the documented knn semantics (core/knn.py
# module docstring): directed pairs (x, y in N_k(x)), candidates {x} ∪ N_k(x)
# ---------------------------------------------------------------------------
def pald_knn_reference(D, k, ties="drop"):
    D = np.asarray(D, np.float64)
    n = D.shape[0]
    C = np.zeros((n, n))
    for x in range(n):
        row = np.where(np.arange(n) == x, np.inf, D[x])
        order = np.lexsort((np.arange(n), row))  # (distance, index) ties
        nbr = [int(i) for i in order[:k]]
        cand = [x] + nbr
        for y in nbr:
            dxy = D[x, y]

            def fw(dxz, dyz):
                s = (dxz < dxy) or (dyz < dxy)
                if ties != "split":
                    return float(s)
                return 1.0 if s else (0.5 if (dxz == dxy or dyz == dxy)
                                      else 0.0)

            U = sum(fw(D[x, z], D[y, z]) for z in cand)
            if U == 0:
                continue
            w = 1.0 / U
            for z in cand:
                do, dt = D[x, z], D[y, z]
                if ties == "drop":
                    s = float(do < dt and do < dxy)
                elif ties == "ignore":
                    s = float((do < dt or (do == dt and x > y)) and do < dxy)
                else:
                    s = (float(do < dt) + 0.5 * (do == dt)) * (
                        float(do < dxy) + 0.5 * (do == dxy))
                C[x, z] += s * w
    return C / max(n - 1, 1)


@functools.lru_cache(maxsize=None)
def _tied_case():
    """Duplicated integer points: exact ties in every comparison class."""
    rng = np.random.default_rng(3)
    base = rng.integers(-4, 5, size=(10, 3)).astype(np.float32)
    X = np.vstack([base, base[:5]])
    D = np.asarray(features.cdist_reference(X, metric="sqeuclidean"),
                   np.float64)
    return X, D


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------
def test_knn_selection_sorted_and_self_free():
    D = _D(23)
    g = knn.knn_from_distances(D, k=6)
    assert g.indices.shape == (23, 6) and g.distances.shape == (23, 6)
    idx, dist = np.asarray(g.indices), np.asarray(g.distances)
    for x in range(23):
        assert x not in idx[x]
        assert (np.diff(dist[x]) >= 0).all()  # sorted ascending
        np.testing.assert_array_equal(dist[x], np.asarray(D)[x, idx[x]])


def test_knn_selection_tie_break_is_lower_index():
    # three points all at distance 1 from point 0: k=2 must pick 1 and 2
    D = np.ones((4, 4)) - np.eye(4)
    g = knn.knn_from_distances(jnp.asarray(D), k=2)
    np.testing.assert_array_equal(np.asarray(g.indices)[0], [1, 2])
    np.testing.assert_array_equal(np.asarray(g.indices)[3], [0, 1])


def test_knn_from_features_matches_from_distances():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(37, 4)).astype(np.float32)
    D = features.cdist_reference(X, metric="euclidean")
    gd = knn.knn_from_distances(D, k=5)
    # small row_chunk exercises the chunked (and row-padded) path
    gf = knn.knn_from_features(jnp.asarray(X), k=5, metric="euclidean",
                               row_chunk=8)
    np.testing.assert_array_equal(np.asarray(gd.indices),
                                  np.asarray(gf.indices))
    np.testing.assert_allclose(np.asarray(gd.distances),
                               np.asarray(gf.distances), rtol=1e-6, atol=1e-6)


def test_knn_selection_rejects_k_beyond_n_minus_1():
    with pytest.raises(ValueError, match="exceeds"):
        knn.knn_from_distances(_D(5), k=5)
    with pytest.raises(ValueError, match="exceeds"):
        knn.knn_from_features(jnp.zeros((5, 2)), k=7)


# ---------------------------------------------------------------------------
# dense agreement: the k -> n-1 convergence story
# ---------------------------------------------------------------------------
def test_k_at_least_n_minus_1_is_dense_bitwise():
    """At k >= n-1 the restriction is the identity; the executor runs the
    exact dense path, so the result is BITWISE equal (k is clamped)."""
    D = _D(20)
    Cd = np.asarray(pald.cohesion(D, method="dense"))
    for k in (19, 25, 10_000):
        Ck = np.asarray(pald.cohesion(D, method="knn", k=k))
        np.testing.assert_array_equal(Ck, Cd)


def test_sparse_machinery_at_full_k_converges_to_dense():
    """ops.pald_knn never short-circuits — the sparse machinery itself
    must reproduce dense PaLD at k = n-1 (up to summation order)."""
    D = _D(20)
    Cd = np.asarray(pald.cohesion(D, method="dense"))
    g, vals = ops.pald_knn(D, k=19, normalize=True)
    Cs = np.asarray(knn.scatter_dense(g, vals))
    np.testing.assert_allclose(Cs, Cd, rtol=1e-5, atol=1e-6)


def test_error_shrinks_as_k_grows():
    D = _D(24)
    Cd = np.asarray(pald.cohesion(D, method="dense"))
    errs = [np.abs(np.asarray(pald.cohesion(D, method="knn", k=k)) - Cd).max()
            for k in (4, 12, 23)]
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-5  # k = n-1


# ---------------------------------------------------------------------------
# reference conformance (tie-free and tie-heavy x all modes x impls)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", (1, 4, 11))
def test_knn_matches_reference_tie_free(k):
    D = _D(17, seed=5)
    Cref = pald_knn_reference(np.asarray(D), k)
    C = np.asarray(pald.cohesion(D, method="knn", k=k))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("impl", ("jnp", "interpret"))
def test_knn_duplicates_all_tie_modes(ties, impl):
    _, D = _tied_case()
    Cref = pald_knn_reference(D, 6, ties)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="knn", k=6,
                                 ties=ties, impl=impl))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ties", TIE_MODES)
def test_knn_from_features_duplicates(ties):
    X, D = _tied_case()
    Cref = pald_knn_reference(D, 6, ties)
    C = np.asarray(pald.from_features(jnp.asarray(X), metric="sqeuclidean",
                                      method="knn", k=6, ties=ties))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


def test_impls_are_bit_faithful_to_each_other():
    D = _D(33)
    for ties in TIE_MODES:
        _, vj = ops.pald_knn(D, k=7, impl="jnp", ties=ties)
        _, vi = ops.pald_knn(D, k=7, impl="interpret", ties=ties)
        np.testing.assert_array_equal(np.asarray(vj), np.asarray(vi))


def test_kernel_lane_padding_mask():
    """Padded neighbor columns (the TPU lane-alignment path) must be
    masked out of the focus count and pair weights: values computed on a
    k-padded graph with k_valid set equal the unpadded ones."""
    from repro.kernels.pald_knn import knn_values_pallas

    D = _D(16, seed=9)
    g = knn.knn_from_distances(D, k=5)
    m, k = 16, 5
    kp = 8
    dn_p = jnp.pad(g.distances, ((0, 0), (0, kp - k)),
                   constant_values=jnp.inf)
    idx_p = jnp.pad(g.indices, ((0, 0), (0, kp - k)))
    gt = knn.gather_tile_from_distances(D, g.indices)
    vals = knn_values_pallas(g.distances, gt, g.indices, block=8, k_valid=k,
                             ties="drop", interpret=True)
    for gt_p in (
        # production order: gather real k, zero-pad the tile afterwards
        jnp.pad(gt, ((0, 0), (0, kp - k), (0, kp - k))),
        # junk order: gather through the padded (index-0) columns
        knn.gather_tile_from_distances(D, idx_p),
    ):
        vals_p = knn_values_pallas(dn_p, gt_p, idx_p, block=8, k_valid=k,
                                   ties="drop", interpret=True)[:, :k + 1]
        np.testing.assert_array_equal(np.asarray(vals_p), np.asarray(vals))


# ---------------------------------------------------------------------------
# edge cases: k = 1, tiny n, block tiling
# ---------------------------------------------------------------------------
def test_k1_only_nearest_neighbor_pairs():
    D = _D(12, seed=2)
    Cref = pald_knn_reference(np.asarray(D), 1)
    C = np.asarray(pald.cohesion(D, method="knn", k=1))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)
    # row x is supported only at x and its single neighbor
    assert (np.count_nonzero(C, axis=1) <= 2).all()


def test_tiny_n_fixed_points():
    assert np.all(np.asarray(pald.cohesion(jnp.zeros((1, 1)),
                                           method="knn", k=1)) == 0.0)
    D2 = jnp.asarray([[0.0, 2.0], [2.0, 0.0]])
    np.testing.assert_array_equal(
        np.asarray(pald.cohesion(D2, method="knn", k=1)),
        np.asarray(pald.cohesion(D2, method="dense")))


@pytest.mark.parametrize("block", (4, 7, 64))
def test_block_tiling_is_pure_chunking(block):
    """The row tile is a memory knob, never a semantics knob."""
    D = _D(33)
    base = np.asarray(pald.cohesion(D, method="knn", k=6, block=16))
    np.testing.assert_array_equal(
        base, np.asarray(pald.cohesion(D, method="knn", k=6, block=block)))


# ---------------------------------------------------------------------------
# batched input through the engine's uniform (B, ...) layer
# ---------------------------------------------------------------------------
def test_batched_knn_distance_and_features():
    rng = np.random.default_rng(11)
    Xb = rng.normal(size=(3, 21, 3)).astype(np.float32)
    Db = np.stack([np.asarray(features.cdist_reference(Xb[i])) for i in range(3)])
    Cb = np.asarray(pald.cohesion(jnp.asarray(Db), method="knn", k=5))
    assert Cb.shape == (3, 21, 21)
    for i in range(3):
        Ci = np.asarray(pald.cohesion(jnp.asarray(Db[i]), method="knn", k=5))
        np.testing.assert_allclose(Cb[i], Ci, rtol=1e-6, atol=1e-7)
    Cf = np.asarray(pald.from_features(jnp.asarray(Xb), method="knn", k=5))
    assert Cf.shape == (3, 21, 21)
    np.testing.assert_allclose(Cf, Cb, rtol=1e-5, atol=1e-6)
    # chunked batching is a pure re-chunking
    Cb2 = np.asarray(pald.cohesion(jnp.asarray(Db), method="knn", k=5,
                                   batch=2))
    np.testing.assert_array_equal(Cb, Cb2)


# ---------------------------------------------------------------------------
# plan layer: resolution, validation, explain
# ---------------------------------------------------------------------------
def test_k_pins_method_knn():
    p = pald.plan(_D(), k=5)
    assert p.method == "knn" and p.method_source == "k" and p.k == 5
    assert p.block_z is None and p.impl is not None
    pf = pald.plan(jnp.zeros((8, 3)), kind="features", k=3)
    assert pf.method == "knn" and pf.metric == "euclidean"


def test_k_clamps_to_n_minus_1():
    p = pald.plan(_D(12), method="knn", k=100)
    assert p.k == 11
    assert p.explain()["k"] == 11


def test_knn_validation_errors_name_alternatives():
    D = _D(8)
    with pytest.raises(ValueError, match="only valid with method='knn'"):
        pald.plan(D, method="dense", k=3)
    with pytest.raises(ValueError, match="needs k="):
        pald.plan(D, method="knn")
    with pytest.raises(ValueError, match="k must be >= 1"):
        pald.plan(D, method="knn", k=0)
    with pytest.raises(ValueError, match="only available for method='kernel'"):
        pald.plan(D, method="knn", k=3, schedule="tri")
    with pytest.raises(ValueError, match="block_z= does not apply"):
        pald.plan(D, method="knn", k=3, block_z=8)
    with pytest.raises(ValueError, match="z_chunk= only applies"):
        pald.plan(D, method="knn", k=3, z_chunk=4)
    with pytest.raises(ValueError, match="explicit method"):
        pald.plan(D, k=3, z_chunk=4)


def test_knn_explain_contract():
    p = pald.plan(_D(16), method="knn", k=4, block=8)
    info = p.explain()
    assert info["method"] == "knn" and info["k"] == 4
    assert info["executor"].startswith("repro.kernels.ops.")
    assert info["est_vmem_bytes_per_step"] > 0
    # non-knn plans expose k=None, so the explain schema is uniform
    assert pald.plan(_D(16), method="dense").explain()["k"] is None


def test_knn_registered_cells():
    from repro.core import engine

    cells = set(engine.available_executors())
    assert ("distance", "knn", "dense") in cells
    assert ("features", "knn", "dense") in cells


def test_knn_tuning_pass_key():
    from repro.tuning.autotune import _pass_key

    assert _pass_key("pald_knn", None, k=32) == "pald_knn:k32"
    assert _pass_key("pald_knn", None, "split", k=8) == "pald_knn:k8:t-split"


# ---------------------------------------------------------------------------
# sparse-side utilities
# ---------------------------------------------------------------------------
def test_scatter_dense_layout_and_depths():
    D = _D(14)
    g, vals = ops.pald_knn(D, k=4, normalize=True)
    C = np.asarray(knn.scatter_dense(g, vals))
    v = np.asarray(vals)
    idx = np.asarray(g.indices)
    np.testing.assert_array_equal(np.diag(C), v[:, 0])
    for x in range(14):
        np.testing.assert_array_equal(C[x, idx[x]], v[x, 1:])
    np.testing.assert_allclose(np.asarray(knn.local_depths(vals)),
                               C.sum(axis=1), rtol=1e-6)


def test_sparse_communities_recover_clusters():
    """The regime the knn restriction is designed for (Baron et al.): with
    k at least the community size, strong-tie components recover the
    mixture; at ANY k no component ever spans two true clusters (purity —
    the cross-cluster pairs are never neighbors, so they can never form a
    strong tie)."""
    rng = np.random.default_rng(0)
    npc, c, d = 25, 4, 8
    centers = rng.normal(size=(c, d)) * 12.0
    X = np.concatenate([centers[i] + rng.normal(size=(npc, d))
                        for i in range(c)])
    labels = np.repeat(np.arange(c), npc)
    for k in (8, 24):
        g, vals = ops.pald_knn(jnp.asarray(X, jnp.float32), k=k,
                               kind="features", normalize=True)
        comms = knn.communities(g, vals)
        for comm in comms:  # purity holds at every k
            assert len({labels[m] for m in comm}) == 1
        if k >= npc - 1:  # recovery needs neighborhoods covering communities
            big = sorted(comms, key=len, reverse=True)[:c]
            assert {labels[comm[0]] for comm in big} == set(range(c))
            assert all(len(comm) >= 0.7 * npc for comm in big)
