"""Logical-axis -> mesh-axis partitioning rules.

Model code annotates every parameter dimension with a *logical* name
(``repro.models.*`` init functions return spec trees).  This module maps
those to concrete ``PartitionSpec``s for a given mesh and sharding profile:

profile   embed-dim ('embed')        everything tensor-parallel ('heads',
                                     'ff', 'experts', 'vocab', 'mamba_*')
-------   -------------------------  ------------------------------------
dp        replicated                 'model'
fsdp      'data'                     'model'
zero3     ('pod','data') when the    'model'
          mesh has a pod axis

Optimizer state inherits the parameter specs (ZeRO: optimizer shards
wherever the parameter does).  Batch dims shard over all data-parallel axes.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # non-deprecated home of the mesh context (jax >= 0.5)
    from jax._src.mesh import thread_resources as _thread_resources
except ImportError:  # pragma: no cover - older jax
    from jax.interpreters.pxla import thread_resources as _thread_resources

TENSOR_AXES = {"heads", "ff", "experts", "vocab", "mamba_inner", "mamba_heads"}
# head-count axes: shard over 'model' only when the count divides the axis
# (GQA kv heads usually don't — they stay replicated, Megatron-style)
HEAD_AXES = {"q_heads", "kv_heads"}


def ambient_mesh() -> Mesh | None:
    mesh = _thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def model_axis_size() -> int:
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def shard_dim(x, dim: int, axis: str = "model"):
    """Constrain one dim of x to shard over a mesh axis, all others
    UNCONSTRAINED (so batch/data sharding propagates through).

    No-op when there is no ambient mesh / named axis, when the dim doesn't
    divide it, or when the dim is degenerate.
    """
    mesh = ambient_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return x
    m = mesh.shape[axis]
    if x.shape[dim] == 1 or x.shape[dim] % m:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def seq_shard(x, dim: int = 1):
    """Context parallelism: shard a sequence dim over 'model'."""
    return shard_dim(x, dim, "model")


def batch_shard(x, dim: int = 0):
    """Constrain the batch dim over the data-parallel axes.

    The embedding gather otherwise DROPS batch sharding when the table's
    embed axis occupies 'data' (fsdp/zero3 profiles): GSPMD propagates the
    table operand's sharding into the output and replicates batch — every
    downstream activation then runs data-replicated (§Perf 1.2, measured
    16x flop inflation at phi3.5 train_4k).
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    # drop trailing axes until the product divides the batch
    while axes:
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        if x.shape[dim] % prod == 0:
            break
        axes.pop()
    if not axes:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = tuple(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _map_axis(name, profile: str, mesh: Mesh, dim_size: int | None = None):
    if name is None or name in ("layers", "embed_nosplit"):
        return None
    if name in HEAD_AXES:
        if "model" in mesh.axis_names and dim_size is not None \
                and dim_size % mesh.shape["model"] == 0:
            return "model"
        return None
    if name in TENSOR_AXES:
        return "model" if "model" in mesh.axis_names else None
    if name == "embed":
        if profile == "dp":
            return None
        if profile == "zero3":
            ax = data_axes(mesh)
            return ax if len(ax) > 1 else (ax[0] if ax else None)
        return "data" if "data" in mesh.axis_names else None
    raise ValueError(f"unknown logical axis {name!r}")


def spec_to_pspec(spec: tuple, profile: str, mesh: Mesh, shape=None) -> P:
    sizes = shape if shape is not None else (None,) * len(spec)
    return P(*(_map_axis(a, profile, mesh, d) for a, d in zip(spec, sizes)))


def param_shardings(specs: Any, profile: str, mesh: Mesh, shapes: Any = None):
    """Map a logical spec tree to a NamedSharding tree.

    ``shapes`` (a matching tree of ShapeDtypeStructs/arrays) lets the
    head-count axes decide divisibility; without it they stay replicated.
    """
    def is_spec(t):
        return isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t
        )

    if shapes is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, spec_to_pspec(s, profile, mesh)),
            specs,
            is_leaf=is_spec,
        )
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    assert len(flat_shapes) == len(flat_specs), "specs/shapes tree mismatch"
    out = [
        NamedSharding(mesh, spec_to_pspec(s, profile, mesh, x.shape))
        for s, x in zip(flat_specs, flat_shapes)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Shard the batch dim over every data axis that divides it."""
    axes = []
    for a in data_axes(mesh):
        sz = mesh.shape[a]
        if batch_size % sz == 0:
            axes.append(a)
            batch_size //= sz
    return P(tuple(axes) if axes else None)


def cache_pspec(mesh: Mesh, batch: int, seq: int, kv_heads: int) -> P:
    """KV-cache (B, S, KV, HD) sharding: batch over data axes; the KV-head
    dim over 'model' when divisible, else the sequence dim (emergent
    sequence-parallel decode attention; DESIGN.md §6.3)."""
    bspec = batch_pspec(mesh, batch)
    m = mesh.shape.get("model", 1)
    if kv_heads % m == 0:
        return P(bspec[0] if bspec else None, None, "model", None)
    if seq % m == 0:
        return P(bspec[0] if bspec else None, "model", None, None)
    return P(bspec[0] if bspec else None, None, None, None)
