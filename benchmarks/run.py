"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Sections:
    fig3   optimization waterfall        (bench_optimizations)
    fig4   block-size tuning             (bench_blocksize)
    table1 pairwise vs triplet           (bench_variants)
    fig9+  scaling + comm model          (bench_scaling)
    sec7   text-analysis application     (bench_text_analysis)
    roofline summary of dry-run JSONs    (roofline), if present
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    args = ap.parse_args()

    t0 = time.time()
    from . import (bench_blocksize, bench_optimizations, bench_scaling,
                   bench_text_analysis, bench_variants, common)

    if args.fast:
        common.emit(bench_optimizations.run(n=512, n_naive=96),
                    header="fig3: optimization waterfall (n=512, --fast)")
        common.emit(bench_blocksize.run(n=512, blocks=(32, 64, 128, 256)),
                    header="fig4: block-size tuning (n=512, --fast)")
        common.emit(bench_variants.run(ns=(128, 256, 512)),
                    header="table1: pairwise vs triplet (--fast)")
    else:
        bench_optimizations.main()
        bench_blocksize.main()
        bench_variants.main()
    bench_scaling.main()
    bench_text_analysis.main()
    from . import bench_graphs
    if args.fast:
        common.emit(bench_graphs.run(ns=(256,)),
                    header="appendixC: PaLD on graph APSP (--fast)")
    else:
        bench_graphs.main()

    here = os.path.dirname(__file__)
    from . import roofline
    for tag, sub in [("baseline", "dryrun_out"), ("optimized", "dryrun_out_opt")]:
        dr = os.path.join(here, sub)
        if os.path.isdir(dr) and os.listdir(dr):
            print(f"# roofline ({tag} dry-run dumps)")
            print(roofline.render(roofline.load(dr)))
            print()
    pald = os.path.join(here, "dryrun_out_pald")
    if os.path.isdir(pald) and os.listdir(pald):
        import glob as _glob
        import json as _json
        print("# pald workload dry-run (paper technique at pod scale)")
        print("| workload | strategy | mesh | GiB/dev | coll GiB/chip | compute_s | coll_s | bottleneck |")
        print("|---|---|---|---|---|---|---|---|")
        for p in sorted(_glob.glob(os.path.join(pald, "*.json"))):
            c = _json.load(open(p))
            if c.get("status") != "ok":
                print(f"| {os.path.basename(p)} | — | — | — | — | — | — | ERROR |")
                continue
            m = c["memory_analysis"]
            gib = (m.get("temp_size_in_bytes", 0) + m.get("argument_size_in_bytes", 0)) / 2**30
            r = c["roofline"]
            print(f"| {c['workload']} ({c.get('dtype','f32')}) | {c['strategy']} | {c['mesh']} "
                  f"| {gib:.2f} | {c['coll_bytes_per_chip']/2**30:.2f} "
                  f"| {r['compute_s']:.2f} | {r['collective_s']:.3f} | {r['bottleneck']} |")
        print()
    print(f"# benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
