"""Large-n community detection with sparse k-NN PaLD (ISSUE 5 + 9 + 10).

    PYTHONPATH=src python examples/pald_knn_clusters.py            # n = 50,000
    PYTHONPATH=src python examples/pald_knn_clusters.py --n 4000   # quick run
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/pald_knn_clusters.py --mesh 4  # sharded

A synthetic mixture of many small gaussian communities at a size that is
INFEASIBLE for every dense path: at n = 50k the distance matrix alone is
10 GiB and the dense pipelines perform ~1.2e14 triplet comparisons, while
the k-NN restriction (Baron et al., arXiv:2108.08864) needs O(n*d) memory
for selection, O(n*k^2) comparisons for cohesion, and never materializes
D.  The whole result lives in the sparse (n, k+1) value layout.

Since ISSUE 9 selection and cohesion run as one fused pipeline
(``ops.select_cohere``): freshly selected (slab, k) neighbor tiles are
handed straight to the cohesion tile body, the tuning cache picks the
selection strategy (direct full-width top_k vs the exact tile-min
prefilter), and the NeighborGraph comes back alongside the values for
the community pass — no second pass over the data.  ``--unfused``
restores the old two-stage path for comparison; both are bitwise
identical.

Communities are recovered with k >= the community size — the regime the
restriction is designed for (each point's neighborhood covers its whole
community, so within-community support survives while cross-community
pairs are never even candidates).

``--mesh P`` (ISSUE 10) runs the same fused pipeline row-sharded across
P devices: feature blocks move by ``--strategy`` (allgather / ring / 2d,
O(n*d) words total), each shard streams its own selection tiles into the
cohesion body, and only the sparse (n, k+1) result is gathered — again
bitwise-identical to the single-device paths.
"""
import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import knn
from repro.kernels import ops


def make_mixture(n: int, comm_size: int, d: int, seed: int = 0):
    """~n points in n // comm_size well-separated gaussian communities."""
    rng = np.random.default_rng(seed)
    c = max(n // comm_size, 1)
    centers = rng.normal(size=(c, d)) * (6.0 * c ** (1.0 / d))
    X = np.concatenate(
        [centers[i] + rng.normal(size=(comm_size, d)) for i in range(c)])
    labels = np.repeat(np.arange(c), comm_size)
    return X.astype(np.float32), labels


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--comm-size", type=int, default=25)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--row-chunk", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--unfused", action="store_true",
                    help="two-stage path (standalone selection, then "
                         "cohesion) instead of the fused pipeline")
    ap.add_argument("--mesh", type=int, default=0, metavar="P",
                    help="shard rows across P devices (ISSUE 10 "
                         "select->cohere shard_map pipeline; on CPU force "
                         "host devices via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=P)")
    ap.add_argument("--strategy", default="ring",
                    choices=["allgather", "ring", "2d"],
                    help="feature-movement strategy for --mesh "
                         "(2d needs even P)")
    args = ap.parse_args()

    X, labels = make_mixture(args.n, args.comm_size, args.d, args.seed)
    n, c = len(X), labels.max() + 1
    dense_gib = n * n * 4 / 2**30
    print(f"[knn] n={n} in {c} communities of {args.comm_size}; "
          f"dense D would be {dense_gib:.1f} GiB + ~{n**3 / 2:.1e} "
          f"comparisons — not attempted")

    Xd = jnp.asarray(X)
    if args.unfused:
        t0 = time.time()
        graph = knn.knn_from_features(Xd, args.k, metric="euclidean",
                                      row_chunk=args.row_chunk)
        jnp.asarray(graph.indices).block_until_ready()
        t_sel = time.time() - t0
        print(f"[knn] neighbor selection (standalone, D never "
              f"materialized): {t_sel:.1f}s -> ({n}, {args.k}) graph")

        t0 = time.time()
        _, vals = ops.pald_knn(Xd, k=args.k, kind="features",
                               graph=graph, normalize=True)
        vals.block_until_ready()
        t_coh = time.time() - t0
        print(f"[knn] sparse cohesion (O(n*k^2)): {t_coh:.1f}s")
        t_pipe = t_sel + t_coh
    elif args.mesh > 1:
        import jax
        from jax.sharding import Mesh
        from repro.core import distributed_knn as dknn
        p = args.mesh
        devs = jax.devices()
        if len(devs) < p:
            raise SystemExit(
                f"--mesh {p}: need {p} devices, have {len(devs)} (set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={p})")
        if args.strategy == "2d":
            if p % 2:
                raise SystemExit("--strategy 2d needs an even --mesh P")
            shape, axnames = (p // 2, 2), ("rows", "cols")
        else:
            shape, axnames = (p,), ("data",)
        mesh = Mesh(np.asarray(devs[:p]).reshape(shape), axnames)
        t0 = time.time()
        graph, vals = dknn.pald_knn_sharded(Xd, mesh, k=args.k,
                                            strategy=args.strategy,
                                            block=args.row_chunk,
                                            normalize=True)
        vals.block_until_ready()
        t_pipe = time.time() - t0
        print(f"[knn] mesh-sharded select->cohere ({args.strategy}, "
              f"mesh {shape}): {t_pipe:.1f}s -> ({n}, {args.k}) graph + "
              f"values, bitwise-equal to the single-device fused path")
    else:
        t0 = time.time()
        graph, vals = ops.select_cohere(Xd, k=args.k, metric="euclidean",
                                        block=args.row_chunk,
                                        normalize=True)
        vals.block_until_ready()
        t_pipe = time.time() - t0
        print(f"[knn] fused select->cohere (one pass, selection tiles "
              f"feed the cohesion body): {t_pipe:.1f}s -> "
              f"({n}, {args.k}) graph + values")
    nbytes = vals.size * 4 / 2**20
    print(f"[knn] pipeline total (select + O(n*k^2) cohesion): "
          f"{t_pipe:.1f}s -> ({n}, {args.k + 1}) values, {nbytes:.0f} MiB "
          f"(vs {dense_gib:.0f} GiB dense C)")

    depths = np.asarray(knn.local_depths(vals))
    tau = knn.universal_threshold(np.asarray(vals))
    print(f"[knn] local depth mean={depths.mean():.4f}  tau={tau:.5f}")

    t0 = time.time()
    comms = knn.communities(graph, np.asarray(vals))
    big = [cc for cc in comms if len(cc) > 1]
    pure = sum(1 for cc in comms if len({labels[m] for m in cc}) == 1)
    covered = sum(len(cc) for cc in big
                  if len(cc) >= 0.5 * args.comm_size
                  and len({labels[m] for m in cc}) == 1)
    print(f"[knn] communities: {time.time() - t0:.1f}s -> "
          f"{len(big)} strong components "
          f"(purity {pure / max(len(comms), 1):.1%}, "
          f"{covered / n:.1%} of points in a majority-recovered community)")
    assert pure == len(comms), "a strong component spans two true communities"
    print("no strong tie ever crosses communities ✓")


if __name__ == "__main__":
    main()
