"""§Perf hillclimb driver: re-dry-run one cell with config overrides and
print the before/after roofline delta against the recorded baseline JSON.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch phi3.5-moe-42b-a6.6b --shape train_4k --mesh single \
        --set moe_shard_constraints=True [--microbatches 4] [--save NAME]

Must run in a fresh process (forces 512 host devices).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json


def parse_override(s: str):
    k, v = s.split("=", 1)
    if v in ("True", "False"):
        return k, v == "True"
    try:
        return k, int(v)
    except ValueError:
        return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig field override, e.g. moe_shard_constraints=True")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--baseline-dir", default="benchmarks/dryrun_out")
    ap.add_argument("--save", default=None,
                    help="dump the new cell JSON under this tag in --baseline-dir")
    args = ap.parse_args()

    from repro import configs
    from repro.launch import dryrun

    overrides = dict(parse_override(s) for s in args.set)
    cfg = configs.get(args.arch)
    nested = {k: v for k, v in overrides.items() if "." in k}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    for k, v in nested.items():
        outer, inner = k.split(".", 1)
        sub = dataclasses.replace(getattr(cfg, outer), **{inner: v})
        flat[outer] = sub
    cfg = dataclasses.replace(cfg, **flat)
    configs.REGISTRY[cfg.name] = cfg  # run_cell resolves by name

    cell = dryrun.run_cell(
        args.arch, args.shape, args.mesh == "multi",
        q_chunk=args.q_chunk, microbatches=args.microbatches,
    )

    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    base_path = os.path.join(args.baseline_dir, tag + ".json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        if base.get("status") == "ok":
            print("\n=== delta vs baseline ===")
            for k in ("compute_s", "memory_s", "collective_s"):
                b, n = base["roofline"][k], cell["roofline"][k]
                print(f"  {k:13s} {b*1e3:12.2f} -> {n*1e3:12.2f} ms "
                      f"({(b-n)/b*100 if b else 0:+.1f}% less)")
            bm = base["memory_analysis"]; nm = cell["memory_analysis"]
            bb = bm.get("temp_size_in_bytes", 0) + bm.get("argument_size_in_bytes", 0)
            nb = nm.get("temp_size_in_bytes", 0) + nm.get("argument_size_in_bytes", 0)
            print(f"  {'GiB/dev':13s} {bb/2**30:12.2f} -> {nb/2**30:12.2f}")
            print(f"  {'useful_ratio':13s} {base['useful_flop_ratio']:12.3f} -> "
                  f"{cell['useful_flop_ratio']:12.3f}")
    if args.save:
        out = os.path.join(args.baseline_dir, f"{tag}__{args.save}.json")
        cell["overrides"] = overrides
        with open(out, "w") as f:
            json.dump(cell, f, indent=1)
        print(f"saved {out}")


if __name__ == "__main__":
    main()
