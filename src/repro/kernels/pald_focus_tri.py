"""Triangular-schedule Pallas kernel for PaLD pass 1 (block-symmetric).

The dense focus kernel visits all nb x nb block pairs; U is symmetric, so
half that work is mirrored.  This variant enumerates only the
nb(nb+1)/2 upper-triangular block pairs — the paper's triplet-style
symmetry exploitation lifted from scalars to VMEM blocks (DESIGN.md §4.3)
— using scalar-prefetched (xb, yb) index arrays
(``pltpu.PrefetchScalarGridSpec``): grid (npairs, nz), the pair's block
coordinates come from SMEM, and the compacted (npairs, b, b) output is
mirrored into the square U with one cheap jnp scatter outside the kernel.

Cuts pass-1 comparisons from n^3 to ~n^3/2 while keeping perfectly regular
vector access — the resolution of the paper's pairwise/triplet tradeoff
at kernel level.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.weights import DEFAULT_TIES, focus_weight, resolve_weight

__all__ = ["focus_tri_pallas"]


def _focus_tri_kernel(xs_ref, ys_ref, dxz_ref, dyz_ref, dxy_ref, u_ref, *, ties):
    # xs_ref/ys_ref are scalar-prefetch refs (consumed by the index maps);
    # the kernel body itself is identical to the dense focus kernel.
    del xs_ref, ys_ref
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    dxz = dxz_ref[...]  # (b, bz)  rows of the X block
    dyz = dyz_ref[...]  # (b, bz)  rows of the Y block
    dxy = dxy_ref[...]  # (b, b)   D[X, Y]
    bx, b = dxy.shape

    def body(y, acc):
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)      # (b, 1)
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)      # (1, bz)
        m = focus_weight(dxz, row, thr, ties)
        col = jnp.sum(m, axis=1, keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(acc, col, y, axis=1)

    add = jax.lax.fori_loop(0, b, body, jnp.zeros((bx, b), jnp.float32))
    u_ref[0] += add


@functools.partial(jax.jit, static_argnames=("block", "block_z", "interpret",
                                             "ties"))
def focus_tri_pallas(
    D: jnp.ndarray,
    *,
    block: int = 128,
    block_z: int = 512,
    interpret: bool = False,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    """U = local-focus sizes via the upper-triangular block schedule."""
    ties = resolve_weight(ties)
    n = D.shape[0]
    assert n % block == 0 and n % block_z == 0
    nb = n // block
    xs_np, ys_np = np.triu_indices(nb)
    npairs = xs_np.shape[0]
    xs = jnp.asarray(xs_np, jnp.int32)
    ys = jnp.asarray(ys_np, jnp.int32)
    D = D.astype(jnp.float32)

    grid = (npairs, n // block_z)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # D[X, z-chunk]: row block from the prefetched xs
            pl.BlockSpec((block, block_z), lambda t, k, xs, ys: (xs[t], k)),
            # D[Y, z-chunk]
            pl.BlockSpec((block, block_z), lambda t, k, xs, ys: (ys[t], k)),
            # D[X, Y]
            pl.BlockSpec((block, block), lambda t, k, xs, ys: (xs[t], ys[t])),
        ],
        out_specs=pl.BlockSpec(
            (1, block, block), lambda t, k, xs, ys: (t, 0, 0)
        ),
    )
    packed = pl.pallas_call(
        functools.partial(_focus_tri_kernel, ties=ties),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((npairs, block, block), jnp.float32),
        interpret=interpret,
    )(xs, ys, D, D, D)

    # mirror the compacted upper-tri blocks into the square U (O(n^2) move)
    U = jnp.zeros((n, n), jnp.float32)
    U = U.at[xs[:, None, None] * block + jnp.arange(block)[None, :, None],
             ys[:, None, None] * block + jnp.arange(block)[None, None, :]
             ].set(packed)
    # lower triangle by symmetry; diagonal blocks overwrite themselves
    Ut = U.T
    tri = jnp.tril(jnp.ones((n, n), bool), -1)
    return jnp.where(tri, Ut, U)
