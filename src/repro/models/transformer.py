"""Generic decoder-only model covering all assigned architectures.

The layer stack is ``n_repeats`` repetitions of a static ``pattern`` of
sublayers (attn/mamba mixer + dense/moe ffn).  Parameters for each pattern
position are stacked over repeats, and the stack is applied with
``jax.lax.scan`` so the lowered HLO is O(pattern) in size — essential for
compiling 512-device dry-runs of 72-layer models on a CPU host.

Per-layer activation rematerialization (`cfg.remat`) wraps the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2, moe
from repro.configs.base import ModelConfig

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_sublayer(key, cfg: ModelConfig, spec):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["norm"], s["norm"] = L.init_rmsnorm(cfg.d_model)
    if spec.mixer == "attn":
        p["mixer"], s["mixer"] = L.init_attention(ks[0], cfg)
    else:
        p["mixer"], s["mixer"] = mamba2.init_mamba(ks[0], cfg)
    if cfg.use_post_norm:
        p["post_norm"], s["post_norm"] = L.init_rmsnorm(cfg.d_model)
    if spec.ffn != "none":
        p["ffn_norm"], s["ffn_norm"] = L.init_rmsnorm(cfg.d_model)
        if spec.ffn == "dense":
            p["ffn"], s["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
        else:
            p["ffn"], s["ffn"] = moe.init_moe(ks[1], cfg.d_model, cfg.moe)
        if cfg.use_post_norm:
            p["ffn_post_norm"], s["ffn_post_norm"] = L.init_rmsnorm(cfg.d_model)
    return p, s


def init(key, cfg: ModelConfig):
    """Returns (params, specs). Per-position params stacked over repeats."""
    ks = jax.random.split(key, len(cfg.pattern) + 3)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = L.init_embedding(
        ks[0], cfg.padded_vocab, cfg.d_model
    )
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = L.init_embedding(
            ks[1], cfg.padded_vocab, cfg.d_model
        )
    params["final_norm"], specs["final_norm"] = L.init_rmsnorm(cfg.d_model)

    blocks, bspecs = [], []
    for i, spec in enumerate(cfg.pattern):
        def one(k):
            return _init_sublayer(k, cfg, spec)[0]

        rep_keys = jax.random.split(ks[i + 3], cfg.n_repeats)
        stacked = jax.vmap(one)(rep_keys)
        _, s = _init_sublayer(ks[i + 3], cfg, spec)
        s = jax.tree.map(
            lambda ax: ("layers",) + ax,
            s,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                a is None or isinstance(a, str) for a in t
            ),
        )
        blocks.append(stacked)
        bspecs.append(s)
    params["blocks"] = blocks
    specs["blocks"] = bspecs
    return params, specs


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------
def _apply_sublayer(p, cfg: ModelConfig, spec, x, *, positions, cache, q_chunk):
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps, f32=cfg.norm_f32)
    if spec.mixer == "attn":
        h, new_cache = L.attention_apply(
            p["mixer"], cfg, h,
            positions=positions, window=spec.window, kv_cache=cache,
            q_chunk=q_chunk, unroll=cfg.probe_unroll,
        )
    else:
        h, new_cache = mamba2.mamba_apply(p["mixer"], cfg, h, state=cache)
    if cfg.use_post_norm:
        h = L.rmsnorm(p["post_norm"], h, cfg.norm_eps, f32=cfg.norm_f32)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = L.rmsnorm(p["ffn_norm"], x, cfg.norm_eps, f32=cfg.norm_f32)
        if spec.ffn == "dense":
            h = L.mlp_apply(p["ffn"], h, cfg.act)
        else:
            h, aux = moe.moe_apply(
                p["ffn"], h, cfg.moe, cfg.act,
                shard_constraints=cfg.moe_shard_constraints,
            )
        if cfg.use_post_norm:
            h = L.rmsnorm(p["ffn_post_norm"], h, cfg.norm_eps, f32=cfg.norm_f32)
        x = x + h
    return x, new_cache, aux


def _stack_body(carry, xs, *, cfg: ModelConfig, positions, q_chunk):
    x, aux = carry
    block_params, caches = xs
    new_caches = []
    for i, spec in enumerate(cfg.pattern):
        cache_i = None if caches is None else caches[i]
        x, nc, a = _apply_sublayer(
            block_params[i], cfg, spec, x,
            positions=positions, cache=cache_i, q_chunk=q_chunk,
        )
        aux = aux + a
        new_caches.append(nc)
    if caches is None:
        return (x, aux), None
    return (x, aux), tuple(new_caches)


def forward(
    params,
    cfg: ModelConfig,
    *,
    tokens: Optional[Array] = None,      # (B, S) int32
    embeds: Optional[Array] = None,      # (B, S, d) for audio/vlm stubs
    positions: Optional[Array] = None,   # (S,)
    caches=None,                         # pytree stacked over repeats, or None
    q_chunk: int = 512,
    last_only: bool = False,             # LM head on the final position only
):
    """Returns (logits (B, S, V), new_caches, aux_loss)."""
    if embeds is None:
        x = params["embed"]["embedding"][tokens]
    else:
        x = embeds
    if cfg.batch_shard_constraint:
        from repro.sharding import partition as _part
        x = _part.batch_shard(x, dim=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)

    body = functools.partial(
        _stack_body, cfg=cfg, positions=positions, q_chunk=q_chunk
    )
    if cfg.remat != "nothing":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
        body = jax.checkpoint(body, policy=policy)

    xs = (tuple(params["blocks"]), caches)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs,
        unroll=max(cfg.scan_unroll, 1),
    )

    if last_only:
        # prefill: only the final position feeds sampling — skipping the
        # other S-1 rows cuts LM-head flops and the (B, S, V) logits buffer
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, f32=cfg.norm_f32)
    head = params["embed" if cfg.tie_embeddings else "lm_head"]["embedding"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the table-padding rows; elementwise, so sharding-friendly
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(L.NEG_INF, logits.dtype))
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked (over repeats) cache pytree for every pattern position.

    Sliding-window attention layers get a circular cache of ``window`` slots
    (bounding long-context memory); global layers get ``max_len`` slots.
    """
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    R = cfg.n_repeats
    caches = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            Sc = min(spec.window, max_len) if spec.window else max_len
            caches.append({
                "k": jnp.zeros((R, batch, Sc, kvh, hd), dtype),
                "v": jnp.zeros((R, batch, Sc, kvh, hd), dtype),
                "pos": jnp.zeros((R,), jnp.int32),
            })
        else:
            m = cfg.mamba
            d_in = m.expand * cfg.d_model
            H = d_in // m.head_dim
            gn = m.n_groups * m.d_state
            K = m.conv_width
            caches.append({
                "conv_x": jnp.zeros((R, batch, K - 1, d_in), dtype),
                "conv_B": jnp.zeros((R, batch, K - 1, gn), dtype),
                "conv_C": jnp.zeros((R, batch, K - 1, gn), dtype),
                "ssm": jnp.zeros((R, batch, H, m.head_dim, m.d_state), jnp.float32),
            })
    return tuple(caches)
