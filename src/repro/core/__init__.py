"""PaLD core: the paper's contribution as a composable JAX module."""
from . import analysis, engine, features, knn, pairwise, pald, reference, triplet  # noqa: F401
from .features import cdist_reference  # noqa: F401
from .pald import cohesion, from_features, local_depths, plan  # noqa: F401
