"""§Perf hillclimb driver.

Two kinds of cells can be climbed:

``cell`` (legacy default): re-dry-run one model cell with config overrides
and print the before/after roofline delta against the recorded baseline
JSON.  Must run in a fresh process (forces 512 host devices).

    PYTHONPATH=src python -m benchmarks.hillclimb cell \
        --arch phi3.5-moe-42b-a6.6b --shape train_4k --mesh single \
        --set moe_shard_constraints=True [--microbatches 4] [--save NAME]

``blocks``: measure the PaLD kernel block-size candidate grid for one
(n, pass, impl) cell and PERSIST the winner into the autotuner cache that
``block="auto"`` reads (repro.tuning) — results used to be printed and
forgotten; now every climb feeds the dispatcher.

    PYTHONPATH=src python -m benchmarks.hillclimb blocks \
        --n 1024 --pass cohesion_tri [--impl jnp] \
        [--blocks 64,128,256] [--block-z 256,512] [--cache PATH]

(``--pass pald_fused`` keys on ``--d``, ``--pass pald_knn`` on ``--k``;
non-default ``--ties`` modes get their own ``:t-<mode>`` cells and
``--weight <name>`` tunes any registered weight functional into its own
``:w-<name>`` cell.)

``methods``: measure the method crossover (dense/pairwise/triplet) across
n and persist the per-n winner, replacing the hard-coded n<=256 heuristic
behind ``pald.cohesion(method="auto")``.

    PYTHONPATH=src python -m benchmarks.hillclimb methods --ns 64,256,1024

``topk``: climb the streaming neighbor-selection cell (``pald_topk``,
keyed ``k<k>:d<d>`` — selection is weight-independent so there is no
ties axis).  The grid crosses the selection row slab (``--blocks``)
with the tile-min prefilter width (``--tiles``; a candidate >= n, or
the word ``direct``, means the full-width top_k with no prefilter).
The winner feeds ``select_block="auto"`` / ``select_tile="auto"`` in
``pald.plan`` and the ``knn_from_features`` facade.

    PYTHONPATH=src python -m benchmarks.hillclimb topk \
        --n 4096 --d 8 --k 32 [--impl jnp] \
        [--blocks 256,1024] [--tiles 32,64,direct] [--cache PATH]
"""
import argparse
import sys


def parse_override(s: str):
    k, v = s.split("=", 1)
    if v in ("True", "False"):
        return k, v == "True"
    try:
        return k, int(v)
    except ValueError:
        return k, v


def _csv_ints(s: str):
    return tuple(int(x) for x in s.split(",") if x)


def run_cell(args) -> None:
    import dataclasses
    import json
    import os

    from repro import configs
    from repro.launch import dryrun

    overrides = dict(parse_override(s) for s in args.set)
    cfg = configs.get(args.arch)
    nested = {k: v for k, v in overrides.items() if "." in k}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    for k, v in nested.items():
        outer, inner = k.split(".", 1)
        sub = dataclasses.replace(getattr(cfg, outer), **{inner: v})
        flat[outer] = sub
    cfg = dataclasses.replace(cfg, **flat)
    configs.REGISTRY[cfg.name] = cfg  # run_cell resolves by name

    cell = dryrun.run_cell(
        args.arch, args.shape, args.mesh == "multi",
        q_chunk=args.q_chunk, microbatches=args.microbatches,
    )

    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    base_path = os.path.join(args.baseline_dir, tag + ".json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        if base.get("status") == "ok":
            print("\n=== delta vs baseline ===")
            for k in ("compute_s", "memory_s", "collective_s"):
                b, n = base["roofline"][k], cell["roofline"][k]
                print(f"  {k:13s} {b*1e3:12.2f} -> {n*1e3:12.2f} ms "
                      f"({(b-n)/b*100 if b else 0:+.1f}% less)")
            bm = base["memory_analysis"]; nm = cell["memory_analysis"]
            bb = bm.get("temp_size_in_bytes", 0) + bm.get("argument_size_in_bytes", 0)
            nb = nm.get("temp_size_in_bytes", 0) + nm.get("argument_size_in_bytes", 0)
            print(f"  {'GiB/dev':13s} {bb/2**30:12.2f} -> {nb/2**30:12.2f}")
            print(f"  {'useful_ratio':13s} {base['useful_flop_ratio']:12.3f} -> "
                  f"{cell['useful_flop_ratio']:12.3f}")
    if args.save:
        out = os.path.join(args.baseline_dir, f"{tag}__{args.save}.json")
        cell["overrides"] = overrides
        with open(out, "w") as f:
            json.dump(cell, f, indent=1)
        print(f"saved {out}")


def run_blocks(args) -> None:
    from repro.core.weights import resolve_weight
    from repro.tuning import autotune

    kw = {}
    if args.blocks:
        kw["blocks"] = _csv_ints(args.blocks)
    if args.block_z:
        kw["blocks_z"] = _csv_ints(args.block_z)
    if getattr(args, "pass") == "pald_fused":
        kw["d"] = args.d
    if getattr(args, "pass") == "pald_knn":
        kw["k"] = args.k
    if args.weight and args.ties != "drop":
        raise SystemExit("--weight and --ties are contradictory; "
                         "--ties is sugar for the built-in modes")
    # a registered functional tunes (and caches, under :w-<name>) exactly
    # like a tie mode: the functional IS the static knob the kernels key on
    ties = resolve_weight(args.weight) if args.weight else args.ties
    rec = autotune.tune(
        args.n, getattr(args, "pass"), impl=args.impl, path=args.cache,
        iters=args.iters, ties=ties, time_budget=args.budget, **kw,
    )
    cache = autotune.cache_path(args.cache)
    wname = args.weight or args.ties
    print(f"# tuned {getattr(args, 'pass')} n={args.n} "
          f"impl={args.impl or 'default'} weight={wname}")
    for row in rec["grid"]:
        head = f"  block={row['block']:5d} block_z={row['block_z']:5d} "
        if "seconds" in row:
            mark = " <- best" if (row["block"], row["block_z"]) == (
                rec["block"], rec["block_z"]) else ""
            print(f"{head}{row['seconds']*1e3:10.2f} ms{mark}")
        elif row.get("failed"):
            print(f"{head}    FAILED: {row['error']}")
        else:
            print(f"{head}   skipped ({row['skipped']})")
    print(f"# cached under {cache}")


def run_topk(args) -> None:
    from repro.tuning import autotune

    kw = {"d": args.d, "k": args.k}
    if args.blocks:
        kw["blocks"] = _csv_ints(args.blocks)
    if args.tiles:
        # "direct" is sugar for a tile >= n (full-width top_k, no prefilter)
        kw["blocks_z"] = tuple(
            args.n if t.strip() == "direct" else int(t)
            for t in args.tiles.split(",") if t.strip()
        )
    if args.p and args.p > 1:
        kw["p"] = args.p  # mesh cell: times the sharded body, keys :p<p>
    rec = autotune.tune(
        args.n, "pald_topk", impl=args.impl, path=args.cache,
        iters=args.iters, time_budget=args.budget, **kw,
    )
    print(f"# tuned pald_topk n={args.n} d={args.d} k={args.k} "
          f"impl={args.impl or 'default'}"
          + (f" p={args.p}" if args.p and args.p > 1 else ""))
    for row in rec["grid"]:
        strat = "direct" if row["block_z"] >= args.n else f"tile={row['block_z']}"
        head = f"  block={row['block']:5d} {strat:12s} "
        if "seconds" in row:
            mark = " <- best" if (row["block"], row["block_z"]) == (
                rec["block"], rec["block_z"]) else ""
            print(f"{head}{row['seconds']*1e3:10.2f} ms{mark}")
        elif row.get("failed"):
            print(f"{head}    FAILED: {row['error']}")
        else:
            print(f"{head}   skipped ({row['skipped']})")
    print(f"# cached under {autotune.cache_path(args.cache)}")


def run_methods(args) -> None:
    from repro.tuning import autotune

    rows = autotune.tune_methods(ns=_csv_ints(args.ns), path=args.cache,
                                 iters=args.iters)
    for r in rows:
        t = " ".join(f"{m}={s*1e3:.1f}ms" for m, s in r["timings"].items())
        print(f"  n={r['n']:6d} best={r['method']:9s} {t}")
    print(f"# cached under {autotune.cache_path(args.cache)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd")

    cell = sub.add_parser("cell", help="dry-run one model cell with overrides")
    cell.add_argument("--arch", required=True)
    cell.add_argument("--shape", required=True)
    cell.add_argument("--mesh", choices=["single", "multi"], default="single")
    cell.add_argument("--set", action="append", default=[],
                      help="ModelConfig field override, e.g. moe_shard_constraints=True")
    cell.add_argument("--microbatches", type=int, default=1)
    cell.add_argument("--q-chunk", type=int, default=1024)
    cell.add_argument("--baseline-dir", default="benchmarks/dryrun_out")
    cell.add_argument("--save", default=None,
                      help="dump the new cell JSON under this tag in --baseline-dir")

    blocks = sub.add_parser("blocks", help="tune PaLD kernel block sizes into the cache")
    blocks.add_argument("--n", type=int, required=True)
    blocks.add_argument("--pass", required=True,
                        choices=("focus", "cohesion", "focus_tri",
                                 "cohesion_tri", "pald", "pald_tri",
                                 "pald_fused", "pald_knn"))
    blocks.add_argument("--impl", default=None,
                        choices=(None, "jnp", "interpret", "pallas"))
    blocks.add_argument("--d", type=int, default=8,
                        help="feature dim (pald_fused cells key on it)")
    blocks.add_argument("--k", type=int, default=16,
                        help="neighborhood size (pald_knn cells key on it)")
    blocks.add_argument("--ties", default="drop",
                        choices=("drop", "split", "ignore"),
                        help="tie mode (non-default modes get their own cells)")
    blocks.add_argument("--weight", default=None,
                        help="registered weight functional name (e.g. soft, "
                             "kernelized); tunes and caches its own "
                             ":w-<name> cell")
    blocks.add_argument("--blocks", default=None, help="csv candidate blocks")
    blocks.add_argument("--block-z", default=None, help="csv candidate z tiles")
    blocks.add_argument("--iters", type=int, default=3)
    blocks.add_argument("--cache", default=None, help="tuning cache path")
    blocks.add_argument("--budget", type=float, default=None,
                        help="wall-seconds budget for the whole sweep; "
                             "remaining candidates record skipped rows")

    methods = sub.add_parser("methods", help="tune the method crossover into the cache")
    methods.add_argument("--ns", default="64,128,256,512,1024")
    methods.add_argument("--iters", type=int, default=3)
    methods.add_argument("--cache", default=None)

    topk = sub.add_parser("topk", help="tune streaming neighbor selection "
                                       "(pald_topk) into the cache")
    topk.add_argument("--n", type=int, required=True)
    topk.add_argument("--d", type=int, default=8)
    topk.add_argument("--k", type=int, default=16)
    topk.add_argument("--impl", default=None,
                      choices=(None, "jnp", "interpret", "pallas"))
    topk.add_argument("--blocks", default=None,
                      help="csv selection row-slab candidates")
    topk.add_argument("--tiles", default=None,
                      help="csv prefilter tile candidates; >= n or the word "
                           "'direct' means full-width top_k")
    topk.add_argument("--p", type=int, default=None,
                      help="mesh device count: tune the SHARDED "
                           "select->cohere cell (pald_topk:...:p<p>) on a "
                           "p-device row shard; needs p devices")
    topk.add_argument("--iters", type=int, default=3)
    topk.add_argument("--cache", default=None, help="tuning cache path")
    topk.add_argument("--budget", type=float, default=None,
                      help="wall-seconds budget for the whole sweep")

    argv = sys.argv[1:]
    if argv and argv[0] not in ("cell", "blocks", "methods", "topk",
                                "-h", "--help"):
        argv = ["cell"] + argv  # legacy invocation without a subcommand
    args = ap.parse_args(argv)

    if args.cmd == "cell":
        # forces 512 host devices; must be set before the first jax import
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        run_cell(args)
    elif args.cmd == "blocks":
        run_blocks(args)
    elif args.cmd == "methods":
        run_methods(args)
    elif args.cmd == "topk":
        run_topk(args)
    else:
        ap.print_help()


if __name__ == "__main__":
    main()
