"""The shipped examples must actually run (they are the public API demo)."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=480):
    return subprocess.run(
        [sys.executable] + args, capture_output=True, text=True,
        timeout=timeout, cwd="/root/repo",
        env={"PYTHONPATH": "/root/repo/src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # without this, jax probes ~8 minutes for an accelerator
             # backend before falling back to CPU — more than the whole
             # timeout budget of the example itself
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )


def test_quickstart():
    r = _run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "all four methods agree" in r.stdout


def test_serve_lm_smoke():
    r = _run(["examples/serve_lm.py", "--arch", "llama3.2-3b",
              "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[serve]" in r.stdout


def test_pald_knn_clusters_small():
    r = _run(["examples/pald_knn_clusters.py", "--n", "2000"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "no strong tie ever crosses communities" in r.stdout


@pytest.mark.slow
def test_pald_text_analysis_small():
    r = _run(["examples/pald_text_analysis.py", "--max-tokens", "384"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "strong ties" in r.stdout
