"""Streaming top-k selection conformance (ISSUE 9).

Every selection implementation — the streaming Pallas kernel (interpret
mode on CPU), the jnp lax.map scan in both strategies (direct full-width
top_k and the exact tile-min prefilter), and the host-driven chunked
degradation rung — must be BITWISE identical to the reference
``_top_k_rows`` contract: stable ``lax.top_k`` on negated distances,
lower-index-first tie-break, self excluded.  Selection feeds every
downstream sparse result, so a one-ulp or one-rank divergence here is a
silent correctness bug, not a tolerance question.

The fused select->cohere pipeline is covered too: it must bitwise-equal
the two-stage ``knn_from_features`` -> ``ops.pald_knn`` composition
under every built-in weight functional.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import knn
from repro.core.features import dist_tile
from repro.kernels import ops
from repro.kernels.pald_topk import topk_pallas

METRICS = ("sqeuclidean", "euclidean", "cosine", "manhattan")


def _features(n, d, seed=0, with_dups=True):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    if with_dups and n >= 8:
        # duplicated rows force distance ties -> exercises the
        # lower-index-first tie-break in every implementation
        X[n // 3] = X[5]
        X[n - 2] = X[1]
    return X


def _reference(X, k, metric="euclidean", pad_to=None):
    """The contract: masked stable top_k over the full distance row.

    ``pad_to`` computes the distances on a zero-row-padded (m, d) input
    with padded rows/cols masked out — the shape the Pallas kernel's
    tiles see.  Zero-padded ROWS are excluded by masking, but on XLA:CPU
    the distance GEMM itself is only bitwise-stable across shapes for
    SIMD-clean d (the d=4/8 used below); for ragged d the padded GEMM
    can differ from the unpadded one by 1 ulp (Eigen packing), which is
    an XLA property, not a selection bug — on the TPU MXU the per-pair
    contraction order is fixed by d alone.  Tests that exercise ragged d
    therefore compare against the same-shape reference."""
    n = X.shape[0]
    m = pad_to or n
    Xp = np.zeros((m, X.shape[1]), np.float32)
    Xp[:n] = X
    Xd = jnp.asarray(Xp)
    D = dist_tile(Xd, Xd, metric, loop_d=False)
    ids = jnp.arange(m)
    bad = (ids[:, None] == ids[None, :]) | (ids[None, :] >= n)
    dv, di = knn._top_k_rows(jnp.where(bad, -jnp.inf, -D), k)
    return dv[:n], di[:n]


def _check(graph, ref_d, ref_i):
    assert graph.distances.dtype == ref_d.dtype
    assert graph.indices.dtype == ref_i.dtype
    np.testing.assert_array_equal(np.asarray(graph.distances),
                                  np.asarray(ref_d))
    np.testing.assert_array_equal(np.asarray(graph.indices),
                                  np.asarray(ref_i))


@pytest.mark.parametrize("metric", METRICS)
def test_jnp_strategies_match_reference(metric):
    n, d, k = 103, 4, 9  # prime-ish n: every tile/slab path hits padding
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k, metric)
    for tile in (n, 16):  # direct and tile-min prefilter
        g = ops.topk_select(jnp.asarray(X), k, metric=metric,
                            impl="jnp", tile=tile)
        _check(g, ref_d, ref_i)


@pytest.mark.parametrize("metric", METRICS)
def test_chunked_rung_matches_reference(metric):
    n, d, k = 97, 3, 7
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k, metric)
    g = ops.topk_select(jnp.asarray(X), k, metric=metric,
                        impl="chunked", block=32)
    _check(g, ref_d, ref_i)


@pytest.mark.parametrize("metric", METRICS)
def test_streaming_kernel_matches_reference(metric):
    n, d, k = 103, 4, 9
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k, metric)
    g = ops.topk_select(jnp.asarray(X), k, metric=metric,
                        impl="interpret", block=64, tile=32)
    _check(g, ref_d, ref_i)


@pytest.mark.parametrize("k", (1, 33, 102))
def test_edge_k_all_impls(k):
    n, d = 103, 4
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k)
    for kw in ({"impl": "jnp", "tile": n}, {"impl": "jnp", "tile": 16},
               {"impl": "chunked"}, {"impl": "interpret"}):
        g = ops.topk_select(jnp.asarray(X), k, **kw)
        _check(g, ref_d, ref_i)


def test_kernel_direct_entry_matches_top_k_rows():
    """topk_pallas itself (below the ops facade), prime n, RAGGED d.

    d=5 makes the distance GEMM shape-sensitive on XLA:CPU, so the
    reference is computed at the kernel's own padded shape (see
    ``_reference``): this isolates the claim that the streaming
    machinery — self/pad masking, bitonic merge, tie-break — adds zero
    error for any d."""
    n, d, k = 97, 5, 13
    X = _features(n, d)
    m = 128  # pad to one 128-row block
    for metric in METRICS:
        ref_d, ref_i = _reference(X, k, metric, pad_to=m)
        Xp = np.zeros((m, d), np.float32)
        Xp[:n] = X
        vals, idx = topk_pallas(jnp.asarray(Xp), k=k, metric=metric,
                                n_valid=n, block=128, block_z=128,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(vals[:n]),
                                      np.asarray(ref_d))
        np.testing.assert_array_equal(np.asarray(idx[:n]),
                                      np.asarray(ref_i))


def test_tile_visit_order_is_irrelevant():
    """Composite-key merge is a total order over distinct indices, so the
    running best-list is the same whatever order candidate tiles fold in
    — checked by varying block_z, which permutes the fold."""
    n, d, k = 128, 4, 9
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k)
    for bz in (16, 32, 128):
        g = ops.topk_select(jnp.asarray(X), k, impl="interpret",
                            block=64, tile=bz)
        _check(g, ref_d, ref_i)


def test_batched_selection_via_vmap():
    """(B, n, d) stacks: the jnp selection path is vmap-composable and
    each batch element bitwise-matches its own single-item run."""
    B, n, d, k = 3, 64, 4, 7
    Xb = np.stack([_features(n, d, seed=s) for s in range(B)])

    def one(x):
        g = ops.topk_select(x, k, impl="jnp", tile=16)
        return g.distances, g.indices

    dv, di = jax.vmap(one)(jnp.asarray(Xb))
    for b in range(B):
        ref_d, ref_i = _reference(Xb[b], k)
        np.testing.assert_array_equal(np.asarray(dv[b]), np.asarray(ref_d))
        np.testing.assert_array_equal(np.asarray(di[b]), np.asarray(ref_i))


def test_facade_delegates_to_topk_select():
    """knn_from_features stays the standalone entry, backed by the same
    machinery — identical output, including under the tile knob."""
    n, d, k = 103, 4, 9
    X = _features(n, d)
    ref_d, ref_i = _reference(X, k)
    g = knn.knn_from_features(jnp.asarray(X), k)
    _check(g, ref_d, ref_i)
    g2 = knn.knn_from_features(jnp.asarray(X), k, row_chunk=32, tile=16)
    _check(g2, ref_d, ref_i)


@pytest.mark.parametrize("ties", ("drop", "split", "ignore"))
def test_fused_pipeline_bitwise_equals_two_stage(ties):
    n, d, k = 103, 4, 9
    X = jnp.asarray(_features(n, d))
    graph = knn.knn_from_features(X, k)
    _, ref_vals = ops.pald_knn(X, k=k, kind="features", graph=graph,
                               ties=ties)
    for sel in (None, "jnp", "chunked", "interpret"):
        g, vals = ops.select_cohere(X, k=k, select=sel, ties=ties)
        np.testing.assert_array_equal(np.asarray(g.indices),
                                      np.asarray(graph.indices))
        np.testing.assert_array_equal(np.asarray(vals),
                                      np.asarray(ref_vals))


def test_fused_engine_path_matches_two_stage_dense():
    """from_features(method=knn) end-to-end: fused executor == scattered
    two-stage composition, bitwise."""
    from repro.core import pald

    n, d, k = 64, 4, 7
    X = jnp.asarray(_features(n, d))
    graph = knn.knn_from_features(X, k)
    _, vals = ops.pald_knn(X, k=k, kind="features", graph=graph)
    ref = knn.scatter_dense(graph, vals)
    out = pald.from_features(X, k=k, normalize=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
