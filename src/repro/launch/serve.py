"""Serving driver: batched prefill + decode loop with temperature sampling.

Demonstrates the full inference path (the thing decode_32k / long_500k
dry-run): continuous batch of requests, one prefill, then token-by-token
decode against the KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import reduced
from repro.models.model import Model, cast_floats
from repro.train import serve_step


def sample(key, logits, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    kp, kt, ks = jax.random.split(key, 3)
    params, _ = model.init(kp)
    params = cast_floats(params, jnp.bfloat16)

    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G
    prompts = jax.random.randint(kt, (B, S), 0, cfg.vocab_size, jnp.int32)

    prefill = jax.jit(serve_step.make_prefill_step(cfg))
    decode = jax.jit(serve_step.make_decode_step(cfg))

    caches = model.init_caches(B, max_len)
    t0 = time.time()
    if cfg.modality in ("audio", "vlm"):
        emb = jax.random.normal(kt, (B, S, cfg.d_model), jnp.float32) * 0.02
        logits, caches = prefill(params, {"embeds": emb}, caches)
    else:
        logits, caches = prefill(params, {"tokens": prompts}, caches)
    t_prefill = time.time() - t0

    out = []
    tok = sample(ks, logits, args.temperature)[:, None].astype(jnp.int32)
    out.append(tok)
    t0 = time.time()
    for i in range(1, G):
        ks, kk = jax.random.split(ks)
        logits, caches = decode(params, tok, caches, jnp.asarray(S + i - 1, jnp.int32))
        tok = sample(kk, logits, args.temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    jax.block_until_ready(gen)
    t_decode = time.time() - t0

    print(f"[serve] {cfg.name}: prefill {B}x{S} in {t_prefill*1e3:.1f} ms, "
          f"{G-1} decode steps in {t_decode*1e3:.1f} ms "
          f"({(G-1)*B/max(t_decode,1e-9):,.1f} tok/s)")
    print("[serve] sample generations (token ids):")
    for b in range(min(B, 2)):
        print(f"  req {b}: {list(map(int, gen[b][:16]))} ...")


if __name__ == "__main__":
    main()
