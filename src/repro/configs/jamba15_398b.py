"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576(per-expert)
vocab=65536, Mamba:attn 7:1 interleave, MoE(16e top-2) every other layer.
[arXiv:2403.19887; hf]

Deviation (DESIGN.md §9): paper-Jamba uses Mamba-1 selective scan; this
framework substitutes the Mamba2 SSD block (same state-size interface).
The 72-layer stack is 9 repeats of an 8-layer pattern with attention at
position 4 and MoE on odd positions (1:7 attn:mamba, 1:2 moe:dense).
"""
from .base import LayerSpec, MambaConfig, ModelConfig, MoEConfig

_P = []
for i in range(8):
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    _P.append(LayerSpec(mixer=mixer, ffn=ffn))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    pattern=tuple(_P),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576),
    mamba=MambaConfig(d_state=128, head_dim=64, n_groups=8, conv_width=4,
                      chunk=256, expand=2),
    rope_theta=10000.0,
    sharding_profile="zero3",   # 398B params: ZeRO-3 over all data axes
    remat="full",
    train_microbatches=8,
    subquadratic=True,  # hybrid: 63/72 layers are SSM; 9 attn layers KV-shard
)
