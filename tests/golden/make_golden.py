"""Regenerate the golden PaLD fixture (run from the repo root).

    python tests/golden/make_golden.py

Writes ``pald_golden.npz``: a small fixed dataset plus its exact cohesion
matrix computed once with the O(n^3) entry-wise reference in float64.  The
fixture is committed; ``test_golden.py`` asserts every optimized path still
reproduces it at float32 tolerance — the silent-drift canary that property
tests can't provide.  Only rerun this script if the *semantics* change on
purpose (and say so in the PR).
"""
import os

import numpy as np

N, D_FEAT, SEED = 24, 3, 2023


def main() -> None:
    rng = np.random.default_rng(SEED)
    # two planted communities at different scales — generic PaLD input with
    # comfortable distance gaps (no near-ties to make f32 paths flip)
    a = rng.normal(size=(10, D_FEAT)) * 0.6
    b = rng.normal(size=(14, D_FEAT)) * 2.0 + 8.0
    X = np.vstack([a, b]).astype(np.float64)
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)

    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    from repro.core import reference

    C = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
    out = os.path.join(os.path.dirname(__file__), "pald_golden.npz")
    np.savez_compressed(out, X=X, D=D, C=C, seed=SEED)
    print(f"wrote {out}: n={len(X)}, sum(C)={C.sum():.6f} (= n/2 = {len(X)/2})")


if __name__ == "__main__":
    main()
