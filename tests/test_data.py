"""Data pipeline: determinism, restart-exactness, shard consistency."""
import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.pipeline import SyntheticTokens
from repro.launch import mesh as meshlib


def test_deterministic_across_instances():
    a = SyntheticTokens(100, 16, 4, seed=3).batch_at(7)
    b = SyntheticTokens(100, 16, 4, seed=3).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_different_steps_differ():
    d = SyntheticTokens(100, 16, 4, seed=3)
    a, b = d.batch_at(0), d.batch_at(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_different_seeds_differ():
    a = SyntheticTokens(100, 16, 4, seed=0).batch_at(0)
    b = SyntheticTokens(100, 16, 4, seed=1).batch_at(0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_labels_are_shifted_tokens():
    d = SyntheticTokens(100, 16, 4, seed=3)
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    # same underlying row: labels[i] == tokens[i] shifted by one
    full = d._host_batch(0, 0, 4)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), full[:, :-1])
    np.testing.assert_array_equal(np.asarray(b["labels"]), full[:, 1:])


def test_vocab_bounds():
    d = SyntheticTokens(37, 64, 8, seed=5)
    b = d.batch_at(11)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 37


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_sharded_batch_matches_host_batch():
    """Each device shard must hold exactly its rows of the host batch."""
    mesh = meshlib.make_test_mesh((8,), ("data",))
    d = SyntheticTokens(100, 16, 8, seed=3, mesh=mesh, batch_spec=P("data"))
    sb = d.batch_at(2)
    host = SyntheticTokens(100, 16, 8, seed=3).batch_at(2)
    np.testing.assert_array_equal(np.asarray(sb["tokens"]), np.asarray(host["tokens"]))
    assert sb["tokens"].sharding.spec == P("data", None)
