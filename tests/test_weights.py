"""The weight-functional subsystem (core/weights.py): contract, registry,
built-in bitwise identity, and the algebraic laws of the new families.

The conformance matrix (test_conformance.py) runs the functionals across
every (method, schedule, impl, batched) cell; this module owns everything
about the subsystem itself: resolution and validation at the plan boundary,
the declared-property surface, the frozen goldens that pin the built-ins to
the PRE-refactor string-dispatched results bit-for-bit, and the limits that
anchor the new families to the built-ins (soft tau -> 0 == split).
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pald
from repro.core.weights import (
    DEFAULT_TIES,
    TIE_MODES,
    WeightFunctional,
    focus_weight,
    index_xwins,
    kernelized,
    register_weight,
    registered_weights,
    resolve_weight,
    soft_threshold,
    support_weight,
    validate_ties,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "weights_builtins_12pt.npz")


def _tie_matrix():
    rng = np.random.default_rng(42)
    A = rng.integers(1, 6, size=(12, 12))
    D = np.triu(A, 1)
    return (D + D.T).astype(np.float64)


# ---------------------------------------------------------------------------
# registry and resolution
# ---------------------------------------------------------------------------
def test_builtins_registered():
    names = registered_weights()
    for mode in TIE_MODES:
        assert mode in names
    assert "soft" in names and "kernelized" in names


def test_resolve_weight_name_instance_none():
    w = resolve_weight("split")
    assert isinstance(w, WeightFunctional) and w.name == "split"
    assert resolve_weight(w) is w
    assert resolve_weight(None).name == DEFAULT_TIES


def test_resolve_unknown_lists_registered():
    with pytest.raises(ValueError) as ei:
        resolve_weight("bogus")
    msg = str(ei.value)
    for name in registered_weights():
        assert name in msg


def test_validate_ties_lists_registered():
    """Knob-validation errors enumerate REGISTERED functionals, not a
    hardcoded tuple — user-registered families are discoverable."""
    with pytest.raises(ValueError) as ei:
        validate_ties("soft")  # registered, but not a built-in mode
    msg = str(ei.value)
    for name in registered_weights():
        assert name in msg


def test_register_duplicate_rejected_and_overwrite():
    w = WeightFunctional("drop", lambda *a: a[0], lambda *a: a[0])
    with pytest.raises(ValueError):
        register_weight(w)


def test_user_registered_functional_resolves_and_runs():
    name = "test-harsh"
    if name not in registered_weights():
        # strict focus, all-or-nothing support (like drop) — registered at
        # test time to prove the registry is open
        base = resolve_weight("drop")
        register_weight(WeightFunctional(
            name, base.focus, base.support, is_strict=True))
    D = jnp.asarray(_tie_matrix())
    C = pald.cohesion(D, method="dense", weight=name)
    Cd = pald.cohesion(D, method="dense", ties="drop")
    np.testing.assert_array_equal(np.asarray(C), np.asarray(Cd))
    assert name in registered_weights()


def test_parametrized_factories_memoize():
    assert soft_threshold(0.05) is soft_threshold(0.05)
    assert soft_threshold(0.05) is not soft_threshold(0.1)
    assert kernelized(2.0) is kernelized(2.0)
    assert soft_threshold(0.05).name == "soft@0.05"
    assert kernelized(2.0).name == "kernelized@2"


def test_properties_surface():
    p = resolve_weight("ignore").properties()
    assert p["needs_index_tiebreak"] and p["is_strict"]
    assert resolve_weight("split").conserves_mass
    assert resolve_weight("soft").conserves_mass
    assert not resolve_weight("kernelized").conserves_mass


# ---------------------------------------------------------------------------
# plan boundary: ties= sugar vs weight=, explain()
# ---------------------------------------------------------------------------
def test_contradictory_ties_and_weight_rejected():
    D = jnp.asarray(_tie_matrix())
    with pytest.raises(ValueError) as ei:
        pald.plan(D, ties="drop", weight="soft")
    msg = str(ei.value)
    assert "contradictory" in msg
    for name in registered_weights():
        assert name in msg


def test_matching_ties_and_weight_allowed():
    D = jnp.asarray(_tie_matrix())
    p = pald.plan(D, ties="split", weight="split")
    assert p.weight.name == "split"


def test_ties_sugar_rejects_non_builtin():
    D = jnp.asarray(_tie_matrix())
    with pytest.raises(ValueError):
        pald.plan(D, ties="soft")  # reachable via weight= only


def test_explain_reports_functional_and_properties():
    D = jnp.asarray(_tie_matrix())
    p = pald.plan(D, weight=soft_threshold(0.05))
    info = p.explain()
    assert info["weight"] == "soft@0.05"
    assert info["weight_properties"]["conserves_mass"] is True
    p2 = pald.plan(D, ties="ignore")
    assert p2.explain()["weight"] == "ignore"
    assert p2.explain()["weight_properties"]["needs_index_tiebreak"] is True


def test_weight_instance_through_facade():
    D = jnp.asarray(_tie_matrix())
    C1 = np.asarray(pald.cohesion(D, method="pairwise", block=4,
                                  weight=soft_threshold(0.1)))
    C2 = np.asarray(pald.cohesion(D, method="pairwise", block=4,
                                  weight="soft"))
    np.testing.assert_array_equal(C1, C2)  # same memoized instance


# ---------------------------------------------------------------------------
# built-ins: bitwise-identical to the pre-refactor string-dispatched layer
# (goldens frozen from the commit preceding this refactor)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("method", ("dense", "pairwise", "triplet", "kernel"))
def test_builtins_bitwise_vs_prerefactor_goldens(method, ties):
    golden = np.load(GOLDEN)
    D = jnp.asarray(_tie_matrix())
    kw = dict(method=method, ties=ties)
    if method != "dense":
        kw["block"] = 4
    if method == "kernel":
        kw.update(impl="interpret", block_z=4)
    C = np.asarray(pald.cohesion(D, **kw))
    np.testing.assert_array_equal(C, golden[f"{method}_{ties}"])


# ---------------------------------------------------------------------------
# new families: anchoring laws
# ---------------------------------------------------------------------------
def test_soft_threshold_recovers_split_in_limit():
    """tau -> 0 hardens both sigmoids to half-steps; on integer distances
    the saturation is exact, so the limit equals ``split`` EXACTLY."""
    D = jnp.asarray(_tie_matrix())
    Cs = np.asarray(pald.cohesion(D, method="dense",
                                  weight=soft_threshold(1e-4)))
    Cp = np.asarray(pald.cohesion(D, method="dense", ties="split"))
    np.testing.assert_array_equal(Cs, Cp)


def test_soft_threshold_conserves_mass_unnormalized():
    D = jnp.asarray(_tie_matrix())
    n = D.shape[0]
    C = np.asarray(pald.cohesion(D, method="dense", normalize=False,
                                 weight="soft"))
    assert abs(C.sum() - n * (n - 1) / 2) < 1e-3


def test_kernelized_bounded_by_drop_mass():
    """Kernelized support leaks share to the out-of-focus role like drop;
    its total mass sits between drop's and the conserved maximum."""
    D = jnp.asarray(_tie_matrix())
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    Ck = np.asarray(pald.cohesion(D, method="dense", normalize=False,
                                  weight="kernelized")).sum()
    assert Ck <= pairs * (1 + 1e-5)


def test_smooth_functionals_finite_on_padded_input():
    """+inf padding (non-multiple n through blocked paths) must never leak
    nan out of the smooth families — the _safe_unit guard contract."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(13, 3))  # 13: forces padding at block=4
    D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    for w in ("soft", "kernelized"):
        C = np.asarray(pald.cohesion(jnp.asarray(D), method="pairwise",
                                     block=4, weight=w))
        assert np.isfinite(C).all(), w
        Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense",
                                      weight=w))
        np.testing.assert_allclose(C, Cd, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# dispatcher / tiebreak contract
# ---------------------------------------------------------------------------
def test_support_ignore_requires_own_wins():
    d = jnp.ones((2, 2))
    with pytest.raises(ValueError):
        support_weight(d, d, d, "ignore", None)


def test_dispatchers_accept_strings_and_instances():
    d0 = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])
    a = focus_weight(d0, d0, d0, "drop")
    b = focus_weight(d0, d0, d0, resolve_weight("drop"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_index_xwins_matches_global_comparison():
    got = np.asarray(index_xwins(4, 3, 2, 5))
    rows = 4 + np.arange(3)
    cols = 2 + np.arange(5)
    np.testing.assert_array_equal(got, rows[:, None] > cols[None, :])


def test_no_dense_square_xwins():
    """The dense (n, n) tiebreak materialization was deleted on purpose;
    per-tile derivation via offsets is the only form."""
    from repro.core import ties as ties_mod
    from repro.core import weights as weights_mod

    assert not hasattr(weights_mod, "square_xwins")
    assert not hasattr(ties_mod, "square_xwins")


# ---------------------------------------------------------------------------
# tuning cache keys
# ---------------------------------------------------------------------------
def test_tuning_keys_gain_weight_component():
    from repro.tuning.autotune import _pass_key

    assert _pass_key("pald_focus", None) == "pald_focus"
    assert _pass_key("pald_focus", None, ties="drop") == "pald_focus"
    assert _pass_key("pald_focus", None, ties="split") == "pald_focus:t-split"
    assert (_pass_key("pald_focus", None, ties=resolve_weight("split"))
            == "pald_focus:t-split")
    assert (_pass_key("pald_focus", None, ties=resolve_weight("soft"))
            == "pald_focus:w-soft")
    assert (_pass_key("pald_focus", None, ties=soft_threshold(0.05))
            == "pald_focus:w-soft@0.05")
