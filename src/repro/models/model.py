"""Model façade: build/init/apply + modality frontend stubs.

Per the brief, ``[audio]`` / ``[vlm]`` architectures specify the transformer
*backbone* only; the modality frontend is a stub whose job is to provide
precomputed frame/patch embeddings with the right shapes (see
``repro.launch.dryrun.input_specs``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import transformer

Array = jnp.ndarray


def cast_floats(tree, dtype):
    def c(x):
        if isinstance(x, jnp.ndarray) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)


class Model:
    """Functional wrapper binding a config to init/apply entry points."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params -------------------------------------------------------------
    def init(self, key):
        return transformer.init(key, self.cfg)

    # -- full-sequence forward (train / scoring) ----------------------------
    def apply(self, params, batch: dict, *, q_chunk: int = 512):
        """batch: {"tokens": (B,S)} or {"embeds": (B,S,d)}.
        Returns (logits, aux_loss)."""
        logits, _, aux = transformer.forward(
            params, self.cfg,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            q_chunk=q_chunk,
        )
        return logits, aux

    # -- serving ------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return transformer.init_caches(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch: dict, caches, *, q_chunk: int = 512):
        """Run the prompt through the model, filling caches.
        Returns (last-token logits (B, V), caches)."""
        logits, caches, _ = transformer.forward(
            params, self.cfg,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            caches=caches, q_chunk=q_chunk, last_only=True,
        )
        return logits[:, -1], caches

    def decode_step(self, params, token: Array, caches, pos: Array):
        """One decode step.  token: (B, 1) int32 (or (B,1,d) embeds);
        pos: scalar int32 position.  Returns (logits (B, V), caches)."""
        kw: dict[str, Any] = {}
        if token.ndim == 3:
            kw["embeds"] = token
        else:
            kw["tokens"] = token
        logits, caches, _ = transformer.forward(
            params, self.cfg,
            positions=jnp.full((1,), pos, jnp.int32),
            caches=caches, **kw,
        )
        return logits[:, -1], caches


# ---------------------------------------------------------------------------
# modality frontend stubs
# ---------------------------------------------------------------------------
def audio_frontend_stub(key, batch: int, seq: int, d_model: int, dtype=jnp.bfloat16):
    """Pretend-EnCodec frame embeddings (musicgen): (B, S, d)."""
    return jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02


def vision_frontend_stub(key, batch: int, seq: int, d_model: int, dtype=jnp.bfloat16):
    """Pretend-InternViT patch embeddings projected to LM width: (B, S, d)."""
    return jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02
