"""Cohesion-matrix analysis: universal threshold, strong ties, communities.

Follows Berenhaut, Moore & Melvin (PNAS 2022), the paper's reference [2]:

* the *universal threshold* for distinguishing strong from weak ties is half
  the mean self-cohesion:  tau = mean(diag(C)) / 2;
* the strong-tie matrix keeps symmetrized cohesion min(c_xy, c_yx) where it
  exceeds tau;
* communities are the connected components of the strong-tie graph.
"""
from __future__ import annotations

import numpy as np

__all__ = ["universal_threshold", "strong_ties", "communities", "top_ties"]


def universal_threshold(C: np.ndarray) -> float:
    """tau = mean(diag(C)) / 2 — half the mean self-cohesion.

    Assumes C is the NORMALIZED cohesion matrix (``pald.cohesion`` /
    ``from_features`` with the default ``normalize=True``, i.e. entries
    carry the 1/(n-1) factor).  On an un-normalized C every entry — diagonal
    and off-diagonal alike — scales by (n-1), so the *partition* into strong
    and weak ties is unchanged, but the returned tau is on the un-normalized
    scale and must not be compared against normalized cohesion values.
    """
    return float(np.mean(np.diag(C))) / 2.0


def strong_ties(C: np.ndarray, threshold: float | None = None) -> np.ndarray:
    """Symmetrized cohesion, zeroed below the universal threshold."""
    C = np.asarray(C)
    tau = universal_threshold(C) if threshold is None else threshold
    S = np.minimum(C, C.T)
    np.fill_diagonal(S, 0.0)
    S[S < tau] = 0.0
    return S


def communities(C: np.ndarray, threshold: float | None = None) -> list[list[int]]:
    """Connected components of the strong-tie graph (union-find).

    Deterministic output order: components sorted by size (largest first),
    equal sizes broken by smallest member index; members within a component
    are in increasing index order.  Sorting by size alone would leave
    equal-size communities in union-find-root order — an artifact of edge
    iteration, not of the data.
    """
    S = strong_ties(C, threshold)
    n = S.shape[0]
    parent = list(range(n))

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for x, y in zip(*np.nonzero(S)):
        ra, rb = find(int(x)), find(int(y))
        if ra != rb:
            parent[ra] = rb
    groups: dict[int, list[int]] = {}
    for i in range(n):
        groups.setdefault(find(i), []).append(i)
    return sorted(groups.values(), key=lambda g: (-len(g), g[0]))


def top_ties(C: np.ndarray, x: int, k: int = 10) -> list[tuple[int, float]]:
    """Strongest symmetric ties of point x (paper §7 word-cloud analogue).

    ``k`` is clamped to the n-1 real partners: a point has no tie to itself,
    so asking for more must not pad the list with the -inf self-sentinel.
    """
    C = np.asarray(C)
    n = C.shape[0]
    k = min(k, n - 1)
    if k <= 0:
        return []
    S = np.minimum(C, C.T)
    row = S[x].copy()
    row[x] = -np.inf
    idx = np.argsort(row)[::-1][:k]
    return [(int(i), float(row[i])) for i in idx]
