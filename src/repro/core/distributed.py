"""Distributed-memory PaLD under ``jax.shard_map``.

The paper proves sequential communication optimality (W = Theta(n^3/sqrt(M)))
and parallelizes on one shared-memory node.  This module is the
distributed-memory extension (DESIGN.md §5): the same two-pass structure
mapped onto a TPU mesh, with per-device compute delegated to the Pallas
kernel primitives (``repro.kernels.ops.focus_general`` /
``cohesion_general``) and inter-device movement expressed with
``jax.lax`` collectives so XLA can overlap compute with communication.

Strategies
----------
allgather     D row-sharded; one all-gather of D; embarrassing row-parallel.
              Comm n^2 words/device, memory n^2/device.  (OpenMP-pairwise
              analogue: every thread reads all of D.)
ring          D row-sharded; row blocks rotate via ppermute; comm n^2
              words/device but memory only O(n^2/P).  Compute of step s
              overlaps the permute for step s+1.
2d            D block-sharded over (rows x cols) mesh axes; all-gathers along
              each axis; comm ~3 n^2/sqrt(P) words/device -- the SUMMA-style
              communication-optimal schedule (distributed analogue of the
              paper's 3NL-optimal blocking).
2d+pod-stream D as 2d but the slow ``pod`` axis is *streamed*: the per-pod
              row slab rotates across pods via ppermute while both passes
              consume it chunk-by-chunk, so each word crosses the inter-pod
              link once and peak gather memory drops by the pod count
              (the NUMA-placement analogue; DESIGN.md §2).

All strategies return C row-sharded the same way D arrived, un-normalized
(the ``pald_distributed`` wrapper handles padding + 1/(n-1)).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import engine as _engine
from .weights import index_xwins as _xwins_rows

# jax.shard_map is top-level only from jax>=0.5; fall back to the
# experimental location on older versions (this container ships 0.4.x).
try:
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["pald_distributed", "pald_distributed_from_features",
           "shard_map_compat"]


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: new check_vma kwarg vs old check_rep."""
    try:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:  # pre-0.5 jax spells the kwarg check_rep
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def _weights_rows(U_rows: jnp.ndarray, row_offset: jnp.ndarray, n_valid) -> jnp.ndarray:
    """W = 1/U for a row block: zero diagonal (global row == col) and padding."""
    m, n = U_rows.shape
    rows = row_offset + jnp.arange(m)
    diag = rows[:, None] == jnp.arange(n)[None, :]
    W = jnp.where(diag | (U_rows == 0), 0.0, 1.0 / jnp.where(U_rows == 0, 1.0, U_rows))
    if n_valid is not None:
        W = W * (rows[:, None] < n_valid) * (jnp.arange(n)[None, :] < n_valid)
    return W.astype(jnp.float32)


# ---------------------------------------------------------------------------
# 1-D strategies: D row-sharded over a single (flattened) axis
# ---------------------------------------------------------------------------
def _allgather_body(Dloc, *, axis, n_valid, plan):
    m = Dloc.shape[0]
    Dall = jax.lax.all_gather(Dloc, axis, tiled=True)          # (n, n)
    off = jax.lax.axis_index(axis) * m
    U = plan.focus_general(Dloc, Dall, Dloc)                   # (m, n)
    W = _weights_rows(U, off, n_valid)
    xw = (_xwins_rows(off, m, 0, Dall.shape[0])
          if plan.weight.needs_index_tiebreak else None)
    return plan.cohesion_general(Dloc, Dall, Dloc, W, xwins=xw)


def _ring_body(Dloc, *, axis, p, n_valid, plan):
    m, n = Dloc.shape
    fwd = [(j, (j + 1) % p) for j in range(p)]
    r = jax.lax.axis_index(axis)

    def owner_cols(s):
        # after s forward shifts we hold the block originally on (r - s) % p
        return ((r - s) % p) * m

    # ---- pass 1: local-focus rows ----------------------------------------
    def f_step(s, carry):
        blk, U = carry
        nxt = jax.lax.ppermute(blk, axis, fwd)                  # comm ...
        off = owner_cols(s)
        Dxy = jax.lax.dynamic_slice(Dloc, (0, off), (m, m))
        Ublk = plan.focus_general(Dloc, blk, Dxy)               # ... overlaps compute
        U = jax.lax.dynamic_update_slice(U, Ublk, (0, off))
        return nxt, U

    _, U = jax.lax.fori_loop(
        0, p, f_step, (Dloc, jnp.zeros((m, n), jnp.float32))
    )
    W = _weights_rows(U, r * m, n_valid)

    # ---- pass 2: cohesion rows --------------------------------------------
    def c_step(s, carry):
        blk, C = carry
        nxt = jax.lax.ppermute(blk, axis, fwd)
        off = owner_cols(s)
        Dxy = jax.lax.dynamic_slice(Dloc, (0, off), (m, m))
        Wxy = jax.lax.dynamic_slice(W, (0, off), (m, m))
        xw = (_xwins_rows(r * m, m, off, m)
              if plan.weight.needs_index_tiebreak else None)
        C = C + plan.cohesion_general(Dloc, blk, Dxy, Wxy, xwins=xw)
        return nxt, C

    _, C = jax.lax.fori_loop(
        0, p, c_step, (Dloc, jnp.zeros((m, n), jnp.float32))
    )
    return C


# ---------------------------------------------------------------------------
# feature-sharded 1-D strategies: X row-sharded, distances computed on-device
#
# Communicating the (n, d) feature matrix instead of the (n, n) distance
# matrix shrinks every collective by a factor of n/d: the all-gather moves
# n*d words (vs n^2) and the ring rotates (m, d) feature blocks (vs (m, n)
# distance rows).  Each device re-imposes the +inf/zero-diag padding contract
# locally via ``features.masked_dist_tile`` — padded feature rows are zeros,
# which every metric maps to a finite distance, so masking by global index
# is what keeps padded points out of real foci.
# ---------------------------------------------------------------------------
def _feat_allgather_body(Xloc, *, axis, metric, n_valid, plan):
    from .features import masked_dist_tile

    m = Xloc.shape[0]
    nv = n_valid
    Xall = jax.lax.all_gather(Xloc, axis, tiled=True)            # (n, d)
    n = Xall.shape[0]
    if nv is None:
        nv = n
    off = jax.lax.axis_index(axis) * m
    Dall = masked_dist_tile(Xall, Xall, metric, 0, 0, nv)        # (n, n) local
    Dloc = jax.lax.dynamic_slice(Dall, (off, 0), (m, n))         # own rows
    U = plan.focus_general(Dloc, Dall, Dloc)
    W = _weights_rows(U, off, n_valid)
    xw = (_xwins_rows(off, m, 0, n)
          if plan.weight.needs_index_tiebreak else None)
    return plan.cohesion_general(Dloc, Dall, Dloc, W, xwins=xw)


def _feat_ring_body(Xloc, *, axis, p, metric, n_valid, plan):
    from .features import masked_dist_tile

    m = Xloc.shape[0]
    fwd = [(j, (j + 1) % p) for j in range(p)]
    r = jax.lax.axis_index(axis)
    # the z axis of both passes needs every point's features; gathering X is
    # the one O(n d) collective (the ring itself only moves (m, d) blocks)
    Xall = jax.lax.all_gather(Xloc, axis, tiled=True)            # (n, d)
    n = Xall.shape[0]
    nv = n if n_valid is None else n_valid
    Dloc = masked_dist_tile(Xloc, Xall, metric, r * m, 0, nv)    # (m, n)

    def owner_off(s):
        return ((r - s) % p) * m

    # ---- pass 1: local-focus rows -----------------------------------------
    def f_step(s, carry):
        xblk, U = carry
        nxt = jax.lax.ppermute(xblk, axis, fwd)                  # (m, d) comm
        off = owner_off(s)
        Dblk = masked_dist_tile(xblk, Xall, metric, off, 0, nv)  # recomputed
        Dxy = jax.lax.dynamic_slice(Dloc, (0, off), (m, m))
        Ublk = plan.focus_general(Dloc, Dblk, Dxy)
        U = jax.lax.dynamic_update_slice(U, Ublk, (0, off))
        return nxt, U

    _, U = jax.lax.fori_loop(
        0, p, f_step, (Xloc, jnp.zeros((m, n), jnp.float32))
    )
    W = _weights_rows(U, r * m, n_valid)

    # ---- pass 2: cohesion rows --------------------------------------------
    def c_step(s, carry):
        xblk, C = carry
        nxt = jax.lax.ppermute(xblk, axis, fwd)
        off = owner_off(s)
        Dblk = masked_dist_tile(xblk, Xall, metric, off, 0, nv)
        Dxy = jax.lax.dynamic_slice(Dloc, (0, off), (m, m))
        Wxy = jax.lax.dynamic_slice(W, (0, off), (m, m))
        xw = (_xwins_rows(r * m, m, off, m)
              if plan.weight.needs_index_tiebreak else None)
        C = C + plan.cohesion_general(Dloc, Dblk, Dxy, Wxy, xwins=xw)
        return nxt, C

    _, C = jax.lax.fori_loop(
        0, p, c_step, (Xloc, jnp.zeros((m, n), jnp.float32))
    )
    return C


# ---------------------------------------------------------------------------
# 2-D strategy (comm-optimal), optionally streaming over the pod axis
# ---------------------------------------------------------------------------
def _2d_body(Dblk, *, row_axes, col_axis, stream_axis, n_valid, mesh_shape,
             plan):
    mr, mc = Dblk.shape
    gathered_rows = tuple(a for a in row_axes if a != stream_axis)
    # row index offset of this device's X block within the global ordering
    roff = jax.lax.axis_index(row_axes) * mr if len(row_axes) == 1 else (
        jax.lax.axis_index(row_axes[0]) * (mr * mesh_shape[row_axes[1]])
        + jax.lax.axis_index(row_axes[1]) * mr
    )
    coff = jax.lax.axis_index(col_axis) * mc

    # D rows for the local X block, all columns: gather along the column axis.
    Grow = jax.lax.all_gather(Dblk, col_axis, axis=1, tiled=True)     # (mx, n)
    n = Grow.shape[1]
    mx = mr * 1
    if stream_axis is None:
        # full gather along all row axes: slab = all rows, local col block
        slab = jax.lax.all_gather(Dblk, row_axes, axis=0, tiled=True)  # (n, mc)
        nsteps, slab_rows = 1, n
    else:
        # gather along the fast intra-pod axes only; rotate pod slabs
        slab = jax.lax.all_gather(Dblk, gathered_rows, axis=0, tiled=True)
        nsteps, slab_rows = mesh_shape[stream_axis], slab.shape[0]
    npods = nsteps
    fwd = None if stream_axis is None else [
        (j, (j + 1) % npods) for j in range(npods)
    ]
    pod_idx = 0 if stream_axis is None else jax.lax.axis_index(stream_axis)

    def slab_row_offset(s):
        if stream_axis is None:
            return jnp.int32(0)
        return ((pod_idx - s) % npods) * slab_rows

    # ---- pass 1: U[Xi, Yj] = sum_z mask, z streamed in slab chunks ---------
    # slab holds D[chunk_rows, Zj]; by symmetry slab.T = d_{y in Yj, z in chunk}
    def f_step(s, carry):
        blk, U = carry
        nxt = blk if stream_axis is None else jax.lax.ppermute(blk, stream_axis, fwd)
        zoff = slab_row_offset(s)
        dxz = jax.lax.dynamic_slice(Grow, (0, zoff), (mr, slab_rows))
        U = U + plan.focus_general(dxz, blk.T, Dblk)
        return nxt, U

    _, U = jax.lax.fori_loop(0, nsteps, f_step, (slab, jnp.zeros((mr, mc), jnp.float32)))

    # weights need full U rows: gather along the column axis (intra-pod)
    Urow = jax.lax.all_gather(U, col_axis, axis=1, tiled=True)         # (mx, n)
    Wrow = _weights_rows(Urow, roff, n_valid)

    # ---- pass 2: C[Xi, Zj] = sum_y mask * w, y streamed in slab chunks -----
    def c_step(s, carry):
        blk, C = carry
        nxt = blk if stream_axis is None else jax.lax.ppermute(blk, stream_axis, fwd)
        yoff = slab_row_offset(s)
        dxy = jax.lax.dynamic_slice(Grow, (0, yoff), (mr, slab_rows))
        w = jax.lax.dynamic_slice(Wrow, (0, yoff), (mr, slab_rows))
        xw = (_xwins_rows(roff, mr, yoff, slab_rows)
              if plan.weight.needs_index_tiebreak else None)
        C = C + plan.cohesion_general(Dblk, blk, dxy, w, xwins=xw)
        return nxt, C

    _, C = jax.lax.fori_loop(0, nsteps, c_step, (slab, jnp.zeros((mr, mc), jnp.float32)))
    return C


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def pald_distributed(
    D: jnp.ndarray,
    mesh: Mesh,
    *,
    strategy: str = "auto",
    row_axes: Sequence[str] | None = None,
    col_axis: str | None = None,
    pod_stream: bool | None = None,
    normalize: bool = True,
    impl: str | None = None,
    comm_dtype=None,
    block: int | str = "auto",
    block_z: int | str = "auto",
    ties: str | None = None,
    weight=None,
    on_error: str = "raise",
) -> jnp.ndarray:
    """Compute the PaLD cohesion matrix on a device mesh.

    Args:
        D: host/global (n, n) distance matrix; padded internally to shard
            evenly, placed according to the strategy, processed, returned
            unsharded.
        mesh: the ``jax.sharding.Mesh`` to run on.
        strategy: "allgather", "ring", "2d", or "auto" (module docstring
            has the communication/memory tradeoffs); "2d" requires a 2-D
            mesh, optionally with ``pod_stream=True`` on the slow axis.
        row_axes / col_axis: which mesh axes shard rows/columns; default
            all-but-last / last.
        pod_stream: stream the inter-pod row slab ("2d" only).
        normalize: apply the 1/(n-1) factor, like ``pald.cohesion``.
        impl: per-device kernel backend (None = backend default).
        comm_dtype: ``jnp.bfloat16`` moves/gathers distances in bf16
            (halving every collective) and compares in bf16 — PaLD
            depends only on the ORDER of distances, so this is exact
            whenever no two distances fall in the same bf16 ulp.
            Distances that collide round to an exact TIE, so the explicit
            ``ties`` mode governs them: the bf16 result equals
            single-device PaLD on the bf16-cast matrix under the same
            ``ties`` (tests/test_ties.py), instead of silently depending
            on which kernel the shard body dispatches to.  §Perf 3.
        block / block_z: per-device kernel tiles; ``"auto"`` (default)
            resolves them from the persistent tuning cache
            (``repro.tuning``), keyed by the per-device problem size.
        ties: tie-handling mode on every shard body (see
            ``pald.cohesion``); sugar for ``weight=``.
        weight: registered weight-functional name or ``WeightFunctional``
            instance (``core/weights.py``) — resolved once at dispatch
            time and threaded into every shard body, so any registered
            functional runs distributed with no per-strategy forks.
        on_error: "raise" (default) or "fallback" — with "fallback", a
            shard body whose per-device kernel fails at trace/lowering
            time degrades across the remaining impls
            (``core/resilience.guarded_general``) instead of crashing the
            whole sharded run.

    Returns:
        (n, n) float32 cohesion matrix, equal to single-device
        ``pald.cohesion(D, ties=ties)`` for any strategy.

    Raises:
        ValueError: unknown strategy/ties, or a strategy/mesh-shape
            mismatch.

    Example:
        >>> import numpy as np, jax, jax.numpy as jnp
        >>> from jax.sharding import Mesh
        >>> rng = np.random.default_rng(0); X = rng.normal(size=(16, 3))
        >>> D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))
        >>> mesh = Mesh(np.asarray(jax.devices()[:1]), ("dev",))
        >>> C = pald_distributed(jnp.asarray(D), mesh, strategy="ring")
        >>> C.shape
        (16, 16)
    """
    axis_names = list(mesh.axis_names)
    if row_axes is None:
        row_axes = tuple(a for a in axis_names if a != axis_names[-1])
    else:
        row_axes = tuple(row_axes)
    col_axis = col_axis or axis_names[-1]
    if strategy == "auto":
        strategy = "2d" if len(axis_names) >= 2 else "ring"
    if pod_stream is None:
        pod_stream = "pod" in axis_names and strategy == "2d"

    n0 = D.shape[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pr = 1
    for a in row_axes:
        pr *= sizes[a]
    pc = sizes[col_axis]

    if strategy in ("allgather", "ring"):
        p = pr * pc
        flat_axes = tuple(axis_names)
        quantum = p
        spec_in = P(flat_axes, None)
    else:
        quantum = pr * pc  # rows need pr | n, cols pc | n; lcm-ish via pr*pc
        spec_in = P(tuple(row_axes), col_axis)

    m = -(-n0 // quantum) * quantum
    dt = comm_dtype or jnp.float32
    Dp = jnp.full((m, m), jnp.inf, dt)
    Dp = Dp.at[:n0, :n0].set(jnp.asarray(D, dt))
    Dp = Dp.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    n_valid = n0 if m != n0 else None

    # resolve every per-device knob (tiles via the tuning cache, impl, ties)
    # exactly once at dispatch (trace) time, keyed on the per-device row
    # extent; the shard bodies consume the frozen plan instead of re-threading
    # four loose kwargs.  `repro.kernels.ops` still clamps the tiles to each
    # call's actual rectangle.
    m_dev = m // (p if strategy in ("allgather", "ring") else pr)
    local_plan = _engine.plan_local(m_dev, impl=impl, ties=ties,
                                    weight=weight, block=block,
                                    block_z=block_z, on_error=on_error)

    mesh_shape = sizes
    if strategy == "allgather":
        body = functools.partial(
            _allgather_body, axis=flat_axes, n_valid=n_valid, plan=local_plan
        )
        out_spec = P(flat_axes, None)
    elif strategy == "ring":
        body = functools.partial(
            _ring_body, axis=flat_axes, p=p, n_valid=n_valid, plan=local_plan
        )
        out_spec = P(flat_axes, None)
    elif strategy == "2d":
        body = functools.partial(
            _2d_body,
            row_axes=row_axes,
            col_axis=col_axis,
            stream_axis="pod" if pod_stream else None,
            n_valid=n_valid,
            mesh_shape=mesh_shape,
            plan=local_plan,
        )
        out_spec = P(tuple(row_axes), col_axis)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    fn = jax.jit(
        shard_map_compat(body, mesh=mesh, in_specs=spec_in, out_specs=out_spec)
    )
    C = fn(Dp)[:n0, :n0]
    if normalize:
        C = C / max(n0 - 1, 1)
    return C


def pald_distributed_from_features(
    X: jnp.ndarray,
    mesh: Mesh,
    *,
    metric: str = "euclidean",
    strategy: str = "auto",
    normalize: bool = True,
    impl: str | None = None,
    block: int | str = "auto",
    block_z: int | str = "auto",
    ties: str | None = None,
    weight=None,
    on_error: str = "raise",
) -> jnp.ndarray:
    """Distributed PaLD straight from row-sharded feature vectors.

    X is zero-padded to shard evenly over the flattened mesh, row-sharded,
    and each device computes its distance rows locally — the only
    O(n)-scaled communication is feature movement (n*d words), an n/d-fold
    reduction over the distance-sharded strategies.

    Args:
        X: host/global (n, d) feature matrix.
        mesh: the ``jax.sharding.Mesh`` to run on (flattened over all
            axes).
        metric: one of ``features.METRICS``.
        strategy: "allgather" — one all-gather of X; each device holds
            (n, d) features and the (n, n) distances it derives (memory
            n^2/device, but comm drops from n^2 to n*d); or "ring"
            (the "auto" default) — X blocks rotate via ppermute and
            distance row slabs are recomputed per step from the (m, d)
            block in flight (memory O(n^2/P), comm 2 n*d words total).
            The full distance matrix is never communicated; ``allgather``
            is the only strategy that materializes it (per device, by
            construction).
        normalize / impl / block / block_z / ties / weight / on_error: as
            in ``pald_distributed``; ``ties``/``weight`` behave exactly
            as in ``pald.from_features``.

    Returns:
        (n, n) float32 cohesion matrix, equal to single-device
        ``pald.from_features(X, metric=metric, ties=ties)``.

    Raises:
        ValueError: unknown strategy, metric or ties mode.

    Example:
        >>> import numpy as np, jax, jax.numpy as jnp
        >>> from jax.sharding import Mesh
        >>> X = np.random.default_rng(0).normal(size=(16, 3))
        >>> mesh = Mesh(np.asarray(jax.devices()[:1]), ("dev",))
        >>> C = pald_distributed_from_features(jnp.asarray(X), mesh)
        >>> C.shape
        (16, 16)
    """
    if strategy == "auto":
        strategy = "ring"
    if strategy not in ("allgather", "ring"):
        raise ValueError(
            f"unknown feature strategy {strategy!r} "
            "(expected 'allgather' or 'ring')"
        )
    axis_names = tuple(mesh.axis_names)
    p = mesh.size
    X = jnp.asarray(X, jnp.float32)  # explicit boundary cast
    n0, d = X.shape
    m = -(-n0 // p) * p
    Xp = jnp.pad(X, ((0, m - n0), (0, 0)))
    n_valid = n0 if m != n0 else None

    local_plan = _engine.plan_local(m // p, impl=impl, ties=ties,
                                    weight=weight, block=block,
                                    block_z=block_z, on_error=on_error)

    if strategy == "allgather":
        body = functools.partial(
            _feat_allgather_body, axis=axis_names, metric=metric,
            n_valid=n_valid, plan=local_plan,
        )
    else:
        body = functools.partial(
            _feat_ring_body, axis=axis_names, p=p, metric=metric,
            n_valid=n_valid, plan=local_plan,
        )
    fn = jax.jit(
        shard_map_compat(body, mesh=mesh, in_specs=P(axis_names, None),
                         out_specs=P(axis_names, None))
    )
    C = fn(Xp)[:n0, :n0]
    if normalize:
        C = C / max(n0 - 1, 1)
    return C
