"""Robustness of the persistent tuning cache (tuning/autotune.py).

Satellites of the guarded-execution PR: corrupt-cache quarantine, the
locked merge-on-save RMW cycle (two concurrent hillclimb processes must
not lose each other's entries), record validation at lookup, and the
per-candidate failure/time budgets of ``tune``.
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import pytest

import repro
from repro.testing import faults
from repro.tuning import autotune


@pytest.fixture(autouse=True)
def _fresh_harness():
    faults.reset()
    yield
    faults.reset()


def _path(tmp_path, name="blocktune.json"):
    return str(tmp_path / name)


# ---------------------------------------------------------------------------
# corrupt JSON: warn once, quarantine, start fresh
# ---------------------------------------------------------------------------
def test_truncated_cache_is_quarantined_not_swallowed(tmp_path):
    p = _path(tmp_path)
    Path(p).write_text('{"cpu|jnp|256|pald": {"block": 64, "bl')  # truncated
    with pytest.warns(UserWarning, match="corrupt"):
        assert autotune.load_cache(p) == {}
    moved = list(tmp_path.glob("blocktune.json.corrupt-*"))
    assert len(moved) == 1
    assert moved[0].read_text().startswith('{"cpu|jnp|256|pald"')
    assert not os.path.exists(p)  # fresh start
    # the path works normally again
    autotune.save_entry("cpu", "jnp", 64, "pald",
                        {"block": 32, "block_z": 32}, p)
    assert "cpu|jnp|64|pald" in autotune.load_cache(p)


def test_corrupt_cache_warns_exactly_once(tmp_path):
    p = _path(tmp_path)
    Path(p).write_text("not json at all")
    with pytest.warns(UserWarning, match="corrupt"):
        autotune.load_cache(p)
    Path(p).write_text("still not json")
    autotune._MEM.pop(os.path.abspath(p), None)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second warning would fail
        assert autotune.load_cache(p) == {}


def test_non_object_json_is_corrupt_too(tmp_path):
    p = _path(tmp_path)
    Path(p).write_text("[1, 2, 3]")  # valid JSON, wrong shape
    with pytest.warns(UserWarning, match="corrupt"):
        assert autotune.load_cache(p) == {}


# ---------------------------------------------------------------------------
# save_entry: locked merge-on-save
# ---------------------------------------------------------------------------
def test_two_processes_merge_instead_of_losing_entries(tmp_path):
    """The regression this PR fixes: two concurrent writers used to race
    the read-modify-write cycle and clobber each other's entries."""
    p = _path(tmp_path)
    src = str(Path(next(iter(repro.__path__))).resolve().parent)
    script = textwrap.dedent("""
        import sys
        from repro.tuning import autotune
        tag, path = sys.argv[1], sys.argv[2]
        for i in range(1, 16):
            autotune.save_entry("cpu", tag, i, "pald",
                                {"block": 8, "block_z": 8}, path)
    """)
    env = {**os.environ, "PYTHONPATH": src}
    procs = [subprocess.Popen([sys.executable, "-c", script, tag, p], env=env)
             for tag in ("writer-a", "writer-b")]
    for proc in procs:
        assert proc.wait(timeout=120) == 0
    data = json.loads(Path(p).read_text())
    assert len(data) == 30  # every entry from both writers survived


def test_save_entry_merges_a_peers_entry_written_meanwhile(tmp_path):
    p = _path(tmp_path)
    autotune.save_entry("cpu", "jnp", 64, "pald",
                        {"block": 32, "block_z": 32}, p)
    # a peer process writes behind our back (bypassing this process's memo)
    data = json.loads(Path(p).read_text())
    data["cpu|jnp|128|pald"] = {"block": 64, "block_z": 64}
    Path(p).write_text(json.dumps(data))
    autotune.save_entry("cpu", "jnp", 256, "pald",
                        {"block": 128, "block_z": 128}, p)
    merged = json.loads(Path(p).read_text())
    assert set(merged) == {"cpu|jnp|64|pald", "cpu|jnp|128|pald",
                           "cpu|jnp|256|pald"}


def test_save_under_held_lock_times_out_with_warning_but_writes(tmp_path):
    p = _path(tmp_path)
    with faults.locked_tuning_cache(p):
        with pytest.warns(UserWarning, match="could not lock"):
            autotune.save_entry("cpu", "jnp", 64, "pald",
                                {"block": 32, "block_z": 32}, p,
                                lock_timeout=0.2)
    assert "cpu|jnp|64|pald" in json.loads(Path(p).read_text())


# ---------------------------------------------------------------------------
# record validation at lookup: quarantined provenance, never a raise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bad", [
    {"block": -8, "block_z": 64},       # non-positive
    {"block": 0, "block_z": 64},        # zero
    {"block": "64", "block_z": 64},     # wrong type
    {"block": True, "block_z": 64},     # bool is not a tile
    {"block": 64, "block_z": 2.5},      # non-integral float
    {"no_block_at_all": 1},             # missing the tile entirely
])
def test_invalid_tile_records_fall_back_with_quarantine_provenance(
        tmp_path, bad):
    p = _path(tmp_path)
    key = "cpu|jnp|128|pald"
    faults.write_cache(p, {key: bad})
    b, bz, src = autotune.resolve_blocks_ex(128, "pald", impl="jnp",
                                            backend="cpu", path=p)
    db, dbz = autotune._default_blocks(128, "pald")
    assert (b, bz) == (db, dbz)  # the values a fresh cache would give
    assert src == f"quarantined:{key}"


def test_valid_float_tiles_still_accepted(tmp_path):
    p = _path(tmp_path)  # JSON round-trips may produce 64.0
    faults.write_cache(p, {"cpu|jnp|128|pald": {"block": 64.0,
                                                "block_z": 128.0}})
    b, bz, src = autotune.resolve_blocks_ex(128, "pald", impl="jnp",
                                            backend="cpu", path=p)
    assert (b, bz) == (64, 128)
    assert src.startswith("cache:")


def test_invalid_method_record_falls_back_to_heuristic(tmp_path):
    p = _path(tmp_path)
    for bogus in ({"method": "knn"}, {"method": "warp-drive"},
                  {"method": 3}, "not-even-a-dict"):
        faults.write_cache(p, {"cpu|-|128|method": bogus})
        m, src = autotune.method_for_ex(128, backend="cpu", path=p)
        assert m == "dense"  # the n<=256 heuristic
        assert src == "quarantined:cpu|-|128|method"


def test_plan_survives_an_invalid_cached_record(tmp_path, monkeypatch):
    """The end-to-end contract: a poisoned cache must never raise
    mid-plan()."""
    from repro.core import pald
    p = _path(tmp_path)
    monkeypatch.setenv("REPRO_TUNE_CACHE", p)
    import jax
    backend = jax.default_backend()
    faults.write_cache(p, {
        f"{backend}|jnp|64|pald": {"block": "poison"},
        f"{backend}|interpret|64|pald": {"block": "poison"},
        f"{backend}|-|64|method": {"method": "poison"},
    })
    plan = pald.plan(n=64, method="auto", block="auto")
    assert plan.method == "dense"  # the heuristic, not the poisoned record
    pk = pald.plan(n=64, method="kernel", block="auto")
    assert pk.explain()["block_source"].startswith("quarantined:")


# ---------------------------------------------------------------------------
# tune(): per-candidate failure and time budgets
# ---------------------------------------------------------------------------
def test_failed_candidate_records_a_row_and_grid_continues(tmp_path):
    with faults.failing("ops.focus_general", times=1):
        rec = autotune.tune(16, "pald", impl="jnp", blocks=(8, 16),
                            blocks_z=(16,), iters=1, save=False)
    failed = [r for r in rec["grid"] if r.get("failed")]
    ok = [r for r in rec["grid"] if "seconds" in r]
    assert len(failed) == 1 and "injected fault" in failed[0]["error"]
    assert ok and rec["block"] in {r["block"] for r in ok}


def test_all_candidates_failing_raises_instead_of_caching(tmp_path):
    p = _path(tmp_path)
    with faults.failing("ops."):
        with pytest.raises(RuntimeError, match="every candidate failed"):
            autotune.tune(16, "pald", impl="jnp", blocks=(8, 16),
                          blocks_z=(16,), iters=1, path=p)
    assert autotune.load_cache(p) == {}  # nothing worth caching was cached


def test_time_budget_skips_the_remaining_candidates():
    rec = autotune.tune(16, "pald", impl="jnp", blocks=(8, 16, 32),
                        blocks_z=(16,), iters=1, save=False,
                        time_budget=0.0)
    assert [r for r in rec["grid"] if "seconds" in r][0] == rec["grid"][0]
    assert all(r.get("skipped") == "over-budget" for r in rec["grid"][1:])
    assert rec["block"] == rec["grid"][0]["block"]


def test_tune_methods_survives_one_failing_method(tmp_path):
    p = _path(tmp_path)
    # kill only the kernel method's entry points; dense/pairwise are pure
    # jnp paths with no ops fault point, so they keep timing normally
    with faults.failing("ops."):
        out = autotune.tune_methods(
            ns=(16,), methods=("dense", "kernel"), iters=1, path=p)
    rec = out[0]
    assert rec["method"] == "dense"
    assert "kernel" in rec["failed"]
    cached = autotune.load_cache(p)["cpu|-|16|method"]
    assert cached["method"] == "dense"
