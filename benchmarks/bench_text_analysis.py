"""Paper §7 analogue: semantic community analysis on embedding vectors.

No network access, so instead of fastText vectors we build a synthetic
"vocabulary" of n=2712 embedding vectors with planted topic clusters of
varying density — exactly the regime PaLD's universal threshold is built
for — run the full distributed pipeline, and report strong-tie stats plus
wall time (the paper reports 0.178 s at n=2712 / p=32 CPU threads).
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import analysis, distributed, pald
from repro.launch import mesh as meshlib

from .common import emit


def synthetic_embeddings(n: int = 2712, dim: int = 64, topics: int = 40,
                         seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(topics, dim)) * 4.0
    # topic sizes follow a Zipf-ish law; per-topic spread varies 4x
    sizes = np.maximum(1, (n * rng.dirichlet(np.ones(topics) * 0.5))).astype(int)
    sizes[-1] += n - sizes.sum()
    X, label = [], []
    for t, s in enumerate(sizes):
        spread = 0.25 + 1.0 * rng.random()
        X.append(centers[t] + rng.normal(size=(s, dim)) * spread)
        label += [t] * s
    return np.vstack(X)[:n].astype(np.float32), np.asarray(label[:n])


def run() -> list[dict]:
    X, label = synthetic_embeddings()
    n = X.shape[0]
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)

    rows = []
    # sequential blocked
    t0 = time.perf_counter()
    C = np.asarray(pald.cohesion(D, method="triplet", block=256))
    t_seq = time.perf_counter() - t0

    # distributed over all fake devices
    ndev = len(jax.devices())
    mesh = meshlib.make_test_mesh((ndev,), ("data",))
    t0 = time.perf_counter()
    Cd = np.asarray(distributed.pald_distributed(D, mesh, strategy="ring", impl="jnp"))
    t_par = time.perf_counter() - t0
    assert np.allclose(C, Cd, atol=1e-5)

    comms = analysis.communities(C)
    purity = np.mean([
        np.bincount(label[c]).max() / len(c) for c in comms if len(c) > 1
    ])
    rows.append({
        "n": n,
        "seq_seconds": round(t_seq, 3),
        f"par_seconds_p{ndev}": round(t_par, 3),
        "speedup": round(t_seq / t_par, 2),
        "communities": len(comms),
        "mean_purity": round(float(purity), 3),
    })
    return rows


def main() -> None:
    emit(run(), header="section7: text-analysis application (synthetic embeddings, n=2712)")


if __name__ == "__main__":
    main()
