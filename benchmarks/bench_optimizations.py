"""Paper Fig. 3 analogue: the optimization waterfall.

The paper stacks branch-avoidance / blocking / integer-U / tie-dropping on
top of naive C loops.  On TPU/XLA (DESIGN.md §9) branches never exist, so
the waterfall is re-based:

    naive        entry-wise python loops (reference.py), n small
    vectorized   dense branch-free jnp (pairwise.pald_dense)
    blocked      cache-blocked pairwise (pairwise.pald_blocked)
    symmetric    block-symmetric "triplet" (triplet.pald_block_symmetric)

Speedups are reported relative to the PREVIOUS rung, like the paper's
figure; multiply down the column for the total.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.core import pairwise, reference, triplet

from .common import emit, random_distance_matrix, time_fn


def run(n: int = 1024, n_naive: int = 192) -> list[dict]:
    rows = []
    Dn = random_distance_matrix(n_naive)
    t_naive = time_fn(
        lambda: reference.pald_pairwise_reference(Dn, ties="ignore"),
        warmup=0, iters=1,
    )
    # scale the naive O(n^3) python time to n for reference
    t_naive_scaled = t_naive * (n / n_naive) ** 3

    D = jnp.asarray(random_distance_matrix(n))
    Dp = D  # n is a block multiple below
    t_dense = time_fn(functools.partial(pairwise.pald_dense, D, z_chunk=256))
    t_blocked = time_fn(functools.partial(pairwise.pald_blocked, Dp, block=256))
    t_sym = time_fn(functools.partial(triplet.pald_block_symmetric, Dp, block=256))

    prev = t_naive_scaled
    for name, t in [
        ("naive-python (scaled)", t_naive_scaled),
        ("vectorized-dense", t_dense),
        ("blocked-pairwise", t_blocked),
        ("block-symmetric", t_sym),
    ]:
        rows.append({
            "stage": name,
            "seconds": round(t, 4),
            "speedup_vs_prev": round(prev / t, 2),
            "speedup_vs_naive": round(t_naive_scaled / t, 2),
        })
        prev = t
    return rows


def main() -> None:
    emit(run(), header="fig3: optimization waterfall (n=1024)")


if __name__ == "__main__":
    main()
