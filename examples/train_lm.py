"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on synthetic data with checkpointing, then analyze its token
embedding space with PaLD (the paper's technique as a framework feature).

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container a 100M model at batch 8 x seq 256 runs ~1 step/s; use
--steps 30 for a quick pass.  The same script runs unchanged on a TPU pod
with --mesh production.
"""
import argparse

from repro.launch import train as train_cli


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L x d512 (GQA 8/4) x ff2048, 32k vocab — llama-family
    import dataclasses
    from repro import configs
    from repro.configs import base as cb

    cfg100m = dataclasses.replace(
        configs.get("llama3.2-3b"),
        name="llama-100m",
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32000, remat="nothing", sharding_profile="dp",
    )
    # register it so the CLI can find it
    configs.REGISTRY["llama-100m"] = cfg100m
    t, _ = cfg100m.param_count()
    print(f"[train_lm] llama-100m: {t/1e6:.1f}M params")

    train_cli.main([
        "--arch", "llama-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--mesh", args.mesh, "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100", "--log-every", "10",
    ])

    print("[train_lm] analyzing the trained embedding table with PaLD...")
    import subprocess
    import sys
    subprocess.run([
        sys.executable, "examples/pald_text_analysis.py",
        "--ckpt", args.ckpt_dir, "--max-tokens", "1024",
    ], check=False)


if __name__ == "__main__":
    main()
