"""Block-symmetric ("triplet-flavoured") PaLD in pure JAX.

The paper's triplet algorithm (Algorithm 2) exploits the symmetry of unordered
triplets to cut scalar work to ~1.33 n^3 flops at the cost of irregular 6-way
scattered writes -- which is hostile to a (8,128)-VREG vector machine.  The
TPU-idiomatic translation (DESIGN.md §4.3) lifts the symmetry from scalars to
*blocks*: only the nb(nb+1)/2 upper-triangular (X, Y) block pairs are visited,
and each off-diagonal visit performs BOTH role updates

    C[x, z] += (d_xz < d_yz) & (d_xz < d_xy) * W[x, y]   (x-role)
    C[y, z] += (d_yz < d_xz) & (d_yz < d_xy) * W[x, y]   (y-role)

so every unordered pair is touched exactly once, halving comparisons versus
the dense pairwise form while keeping fully regular vector access.  Diagonal
blocks (X == Y) fall back to the dense one-sided update, which already covers
both orders of the pairs inside the block.

Tie handling goes through the shared weight functionals of
``core/weights.py``; each built-in mode matches
``reference.pald_pairwise_reference(ties=mode)`` entry-wise on arbitrary
(tied or not) input.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .pairwise import _weights
from .weights import (DEFAULT_TIES, focus_weight, index_xwins, resolve_weight,
                      support_weight)

__all__ = ["pald_block_symmetric"]


def _tri_pairs(nb: int) -> tuple[np.ndarray, np.ndarray]:
    xs, ys = np.triu_indices(nb)
    return xs.astype(np.int32), ys.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("block", "normalize", "ties"))
def pald_block_symmetric(
    D: jnp.ndarray,
    *,
    block: int = 128,
    normalize: bool = False,
    n_valid: jnp.ndarray | int | None = None,
    ties=DEFAULT_TIES,
) -> jnp.ndarray:
    ties = resolve_weight(ties)
    D = D.astype(jnp.float32)
    n = D.shape[0]
    assert n % block == 0, "caller must pad to a block multiple"
    nb = n // block
    xs, ys = _tri_pairs(nb)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    npairs = int(xs.shape[0])

    # ---- pass 1: local focus, upper-tri block pairs, mirrored -------------
    def focus_loop(i, U):
        xb, yb = xs[i], ys[i]
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))
        Dxy = jax.lax.dynamic_slice_in_dim(Dx, yb * block, block, axis=1)
        m = focus_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None], ties)
        blk = jnp.sum(m, axis=-1, dtype=jnp.float32)
        U = jax.lax.dynamic_update_slice(U, blk, (xb * block, yb * block))
        U = jax.lax.dynamic_update_slice(U, blk.T, (yb * block, xb * block))
        return U

    U = jax.lax.fori_loop(0, npairs, focus_loop, jnp.zeros((n, n), jnp.float32))
    W = _weights(U, n_valid)

    # ---- pass 2: cohesion, both roles per off-diagonal block pair ---------
    def coh_loop(i, C):
        xb, yb = xs[i], ys[i]
        Dx = jax.lax.dynamic_slice(D, (xb * block, 0), (block, n))
        Dy = jax.lax.dynamic_slice(D, (yb * block, 0), (block, n))
        Dxy = jax.lax.dynamic_slice_in_dim(Dx, yb * block, block, axis=1)
        Wxy = jax.lax.dynamic_slice(W, (xb * block, yb * block), (block, block))
        diag = xb == yb
        xw = yw = None
        if ties.needs_index_tiebreak:
            # global-index tiebreak; on diagonal blocks the one-sided x-role
            # visits both orders of every in-block pair, so xw alone covers it
            xw = index_xwins(xb * block, block, yb * block, block)[:, :, None]
            yw = index_xwins(yb * block, block, xb * block, block).T[:, :, None]
        gx = support_weight(Dx[:, None, :], Dy[None, :, :], Dxy[:, :, None],
                            ties, xw)
        add_x = jnp.einsum("xyz,xy->xz", gx, Wxy)
        # y-role: skipped for diagonal blocks (dense one-sided already covers
        # both orders there); masked to zero via `diag`.
        gy = support_weight(Dy[None, :, :], Dx[:, None, :], Dxy[:, :, None],
                            ties, yw)
        add_y = jnp.einsum("xyz,xy->yz", gy, Wxy)
        add_y = jnp.where(diag, 0.0, 1.0) * add_y

        rx = jax.lax.dynamic_slice(C, (xb * block, 0), (block, n))
        C = jax.lax.dynamic_update_slice(C, rx + add_x, (xb * block, 0))
        ry = jax.lax.dynamic_slice(C, (yb * block, 0), (block, n))
        C = jax.lax.dynamic_update_slice(C, ry + add_y, (yb * block, 0))
        return C

    C = jax.lax.fori_loop(0, npairs, coh_loop, jnp.zeros((n, n), jnp.float32))
    if normalize:
        C = C / (n - 1)
    return C


# ---------------------------------------------------------------------------
# engine executor: the block-symmetric cell of the dispatch registry
# (core/engine.py); one unbatched item in, the full per-item pipeline here.
# ---------------------------------------------------------------------------
from . import engine as _engine  # noqa: E402  (registry import, cycle-free)


@_engine.register_executor("distance", "triplet", "dense")
def _exec_triplet(D, plan):
    Dp, n0 = _engine.pad_distance_matrix(D, plan.block)  # f32 boundary cast
    nv = jnp.asarray(n0) if Dp.shape[0] != n0 else None
    C = pald_block_symmetric(Dp, block=plan.block, n_valid=nv,
                             ties=plan.weight)
    C = C[:n0, :n0]
    return C / max(n0 - 1, 1) if plan.normalize else C
