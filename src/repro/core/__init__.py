"""PaLD core: the paper's contribution as a composable JAX module."""
from . import analysis, pairwise, pald, reference, triplet  # noqa: F401
from .pald import cohesion, local_depths  # noqa: F401
