"""Paper Table 1 analogue: pairwise vs triplet running time across n.

The paper's crossover (pairwise wins small-n, triplet wins large-n thanks to
~2x fewer comparisons) shows up here as dense vs block-symmetric.

``run_kernels`` is the kernel-pipeline sibling: the dense (nx, nz, ny) grid
vs the upper-triangular block schedule (pald_focus_tri + pald_cohesion_tri)
through ``repro.kernels.ops``, per pass and for the fused pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features, pairwise, triplet
from repro.kernels import ops as kops
from repro.kernels import ref as kref

from .common import emit, random_distance_matrix, time_fn


def run(ns=(128, 256, 512, 1024, 2048)) -> list[dict]:
    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b = min(256, n)
        tp = time_fn(functools.partial(pairwise.pald_blocked, D, block=b))
        tt = time_fn(functools.partial(triplet.pald_block_symmetric, D, block=b))
        rows.append({
            "n": n,
            "pairwise_s": round(tp, 4),
            "triplet_s": round(tt, 4),
            "triplet_speedup": round(tp / tt, 3),
        })
    return rows


def run_kernels(ns=(256, 512, 1024), impl: str = "jnp",
                block: int = 128, block_z: int = 512) -> list[dict]:
    """Dense kernel grid vs tri block schedule, cohesion pass and fused
    pipeline, on one impl (jnp fallback by default — the dense numbers are
    what `impl='pallas'` block-streams on TPU)."""
    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b, bz = min(block, n), min(block_z, n)
        W = kref.weights_ref(kops.focus(D, block=b, block_z=bz, impl=impl))
        tc_dense = time_fn(functools.partial(
            kops.cohesion_from_weights, D, W, block=b, block_z=bz, impl=impl))
        tc_tri = time_fn(functools.partial(
            kops.cohesion_from_weights, D, W, block=b, block_z=bz, impl=impl,
            schedule="tri"))
        tp_dense = time_fn(functools.partial(
            kops.pald, D, block=b, block_z=bz, impl=impl))
        tp_tri = time_fn(functools.partial(
            kops.pald_tri, D, block=b, block_z=bz, impl=impl))
        rows.append({
            "n": n,
            "impl": impl,
            "cohesion_dense_s": round(tc_dense, 4),
            "cohesion_tri_s": round(tc_tri, 4),
            "cohesion_tri_speedup": round(tc_dense / tc_tri, 3),
            "pald_dense_s": round(tp_dense, 4),
            "pald_tri_s": round(tp_tri, 4),
            "pald_tri_speedup": round(tp_dense / tp_tri, 3),
        })
    return rows


def run_fused(ns=(256, 1024), d: int = 8, metric: str = "sqeuclidean",
              impl: str = "jnp", block: int = 128, block_z: int = 512) -> list[dict]:
    """Fused features→cohesion vs materialize-then-kernel (ISSUE 2 acceptance).

    Both sides are one jit'd function of the same (n, d) feature matrix:
    the materialized side builds the full D with ``cdist_reference`` and
    runs the kernel pipeline on it; the fused side computes distance tiles
    inside the block loops and never holds D.
    """
    rows = []
    for n in ns:
        X = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)),
                        jnp.float32)
        b, bz = min(block, n), min(block_z, n)
        fused = jax.jit(functools.partial(
            kops.pald_fused, metric=metric, block=b, block_z=bz, impl=impl))
        mat = jax.jit(lambda X: kops.pald(
            features.cdist_reference(X, metric=metric),
            block=b, block_z=bz, impl=impl))
        t_fused = time_fn(fused, X)
        t_mat = time_fn(mat, X)
        rows.append({
            "n": n,
            "d": d,
            "metric": metric,
            "impl": impl,
            "fused_s": round(t_fused, 4),
            "materialized_s": round(t_mat, 4),
            "fused_speedup": round(t_mat / t_fused, 3),
        })
    return rows


def run_ties(ns=(128, 256, 512, 1024), impl: str = "jnp",
             block: int = 128, block_z: int = 512,
             repeats: int = 3) -> list[dict]:
    """Tie-mode tile-body cost on the table1 rows (ISSUE 3 acceptance):
    'split' adds two equality masks per tile and 'ignore' an index tiebreak;
    both must stay within ~10% of the strict 'drop' bodies.  Timed on the
    full two-pass kernel pipeline (jnp impl — the same bodies the Pallas
    kernels run on TPU).  Each cell takes the MIN over ``repeats``
    interleaved median-of-3 measurements: wall-clock on shared boxes swings
    2x, and interleaving the modes keeps a load spike from landing entirely
    on one of them."""
    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b, bz = min(block, n), min(block_z, n)
        t = {ties: float("inf") for ties in ("drop", "split", "ignore")}
        for _ in range(repeats):
            for ties in t:
                t[ties] = min(t[ties], time_fn(functools.partial(
                    kops.pald, D, block=b, block_z=bz, impl=impl, ties=ties)))
        rows.append({
            "n": n,
            "impl": impl,
            "drop_s": round(t["drop"], 4),
            "split_s": round(t["split"], 4),
            "ignore_s": round(t["ignore"], 4),
            "split_overhead": round(t["split"] / t["drop"] - 1.0, 3),
            "ignore_overhead": round(t["ignore"] / t["drop"] - 1.0, 3),
        })
    return rows


def run_weights(ns=(256, 512), impl: str = "jnp",
                block: int = 128, block_z: int = 512,
                k: int = 32, repeats: int = 5) -> list[dict]:
    """Weight-functional tile-body cost (ISSUE 8 acceptance: soft <= 15%
    over strict drop on the dense and knn kernels).

    The smooth family trades the built-ins' compare-and-mask tile bodies
    for a smoothstep sigmoid (``core.weights._sigmoid``: clip/abs/mul/add
    only — no transcendental, no division) plus a clipped ramp share;
    this sweep quantifies that cost on the full two-pass dense kernel
    pipeline and on the sparse knn pipeline, same interleaved
    MIN-over-repeats discipline as ``run_ties`` (5 repeats: the overhead
    ratio gate rides on these numbers, and min-of-many is the statistic
    least inflated by shared-runner load spikes).  'kernelized' rides
    along (strict focus pass, smooth support pass only).

    The knn cell is component-timed at 4*n rows (``knn_n`` in the row):
    the top-k graph build is weight-INDEPENDENT, so it is timed once per
    row and the per-functional timing covers only ``kops.knn_values`` on
    the prebuilt graph; the reported ``knn_*_overhead`` is the
    pipeline ratio ``(graph + values_w) / (graph + values_drop) - 1`` —
    what a ``method='knn'`` caller pays — while ``knn_vals_*_s`` keeps
    the undiluted values-stage times in the artifact.  Component timing
    at the larger n makes each measured quantity long enough that a
    scheduler burst on a shared runner cannot flip the gate."""
    from repro.core import knn as _knn

    names = ("drop", "soft", "kernelized")
    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b, bz = min(block, n), min(block_z, n)
        kk = min(k, n - 1)
        kn = 4 * n
        Dk = jnp.asarray(random_distance_matrix(kn))
        graph = jax.block_until_ready(_knn.knn_from_distances(Dk, kk))
        dense = {w: float("inf") for w in names}
        vals = {w: float("inf") for w in names}
        tg = float("inf")
        for _ in range(repeats):
            tg = min(tg, time_fn(functools.partial(
                _knn.knn_from_distances, Dk, kk)))
            for w in names:
                dense[w] = min(dense[w], time_fn(functools.partial(
                    kops.pald, D, block=b, block_z=bz, impl=impl, ties=w)))
                vals[w] = min(vals[w], time_fn(functools.partial(
                    kops.knn_values, Dk, graph, block=b, impl=impl,
                    ties=w)))
        knn = {w: tg + vals[w] for w in names}
        rows.append({
            "n": n,
            "impl": impl,
            "k": kk,
            "knn_n": kn,
            "dense_drop_s": round(dense["drop"], 4),
            "dense_soft_s": round(dense["soft"], 4),
            "dense_kernelized_s": round(dense["kernelized"], 4),
            "knn_graph_s": round(tg, 4),
            "knn_vals_drop_s": round(vals["drop"], 4),
            "knn_vals_soft_s": round(vals["soft"], 4),
            "knn_vals_kernelized_s": round(vals["kernelized"], 4),
            "dense_soft_overhead": round(dense["soft"] / dense["drop"] - 1.0,
                                         3),
            "knn_soft_overhead": round(knn["soft"] / knn["drop"] - 1.0, 3),
            "dense_kernelized_overhead": round(
                dense["kernelized"] / dense["drop"] - 1.0, 3),
            "knn_kernelized_overhead": round(
                knn["kernelized"] / knn["drop"] - 1.0, 3),
        })
    return rows


def run_dispatch(ns=(256, 512), method: str = "triplet",
                 block: int = 128, repeats: int = 3,
                 iters: int = 50) -> list[dict]:
    """Engine dispatch overhead (ISSUE 4 acceptance: <= 2%).

    ``pald.cohesion`` = plan resolution + registry lookup + input checks +
    the registered executor; the executor is byte-for-byte the pre-refactor
    method-branch body, so everything before it is the refactor's added
    cost.  Subtracting two noisy wall-clock timings of the same O(n^3)
    compute cannot resolve a 2% budget (run-to-run swing is ~10% on shared
    boxes), so the machinery is microbenched on its own — ``iters`` calls of
    plan + lookup + checks, no compute — and reported relative to the
    executor's (MIN over ``repeats`` median-of-3) time:

        dispatch_overhead = dispatch_s / direct_s
    """
    import time as _time

    from repro.core import engine, pald as _pald

    rows = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        b = min(block, n)
        p = _pald.plan(D, method=method, block=b)
        ex = engine.get_executor(p.kind, p.method, p.schedule)
        t_direct = float("inf")
        for _ in range(repeats):
            t_direct = min(t_direct, time_fn(lambda: ex(D, p)))
        def bench_machinery(**plan_kwargs):
            # MIN over repeats, like the executor timing: the ratio must not
            # pair one route's load-spiked measurement with the other's
            # fastest observation
            best = float("inf")
            for _ in range(repeats):
                t0 = _time.perf_counter()
                for _ in range(iters):
                    pi = _pald.plan(D, **plan_kwargs)
                    engine.get_executor(pi.kind, pi.method, pi.schedule)
                    engine._check_input(D, pi)
                best = min(best, (_time.perf_counter() - t0) / iters)
            return best

        t_dispatch = bench_machinery(method=method, block=b)
        # the facade's true default path: method='auto' + block='auto' adds
        # the method-crossover and nearest-n tuning-cache scans
        t_dispatch_auto = bench_machinery(method="auto", block="auto")
        rows.append({
            "n": n,
            "method": method,
            "direct_s": round(t_direct, 4),
            "dispatch_us": round(t_dispatch * 1e6, 1),
            "dispatch_auto_us": round(t_dispatch_auto * 1e6, 1),
            "dispatch_overhead": round(t_dispatch / t_direct, 6),
            "dispatch_auto_overhead": round(t_dispatch_auto / t_direct, 6),
        })
    return rows


def run_batched(cells=((3, 128), (3, 256), (2, 512)),
                block: int = 64, d: int = 8) -> list[dict]:
    """Batched (B, n, n)/(B, n, d) throughput vs the per-item loop.

    The engine vmaps one executor over the batch, so the whole batch is one
    compiled call — the serving-path shape.  One distance cell (triplet, the
    large-n winner) and one feature cell (fused) per (B, n).
    """
    from repro.core import pald as _pald

    rows = []
    for B, n in cells:
        rng = np.random.default_rng(n)
        Db = jnp.asarray(np.stack([random_distance_matrix(n, seed=s)
                                   for s in range(B)]))
        Xb = jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
        b = min(block, n)
        for label, batched, loop in (
            ("triplet",
             lambda: _pald.cohesion(Db, method="triplet", block=b),
             lambda: [_pald.cohesion(Db[i], method="triplet", block=b)
                      for i in range(B)]),
            ("fused",
             lambda: _pald.from_features(Xb, block=b, block_z=b),
             lambda: [_pald.from_features(Xb[i], block=b, block_z=b)
                      for i in range(B)]),
        ):
            t_batched = time_fn(batched)
            t_loop = time_fn(loop)
            rows.append({
                "B": B,
                "n": n,
                "method": label,
                "loop_s": round(t_loop, 4),
                "batched_s": round(t_batched, 4),
                "batched_speedup": round(t_loop / t_batched, 3),
                "items_per_s": round(B / t_batched, 2),
            })
    return rows


def main() -> None:
    emit(run(), header="table1: pairwise vs triplet")
    emit(run_kernels(), header="table1b: dense vs tri kernel schedule (jnp impl)")
    emit(run_fused(), header="table1c: fused features vs materialize-then-kernel")
    emit(run_ties(), header="ties: split/ignore tile-body overhead vs strict drop")
    emit(run_weights(), header="weights: soft/kernelized tile-body overhead vs drop")


if __name__ == "__main__":
    main()
