"""gemma2-9b — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Local(4096)/global alternating, logit softcaps.  [arXiv:2408.00118; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    pattern=(
        LayerSpec(mixer="attn", ffn="dense", window=4096),
        LayerSpec(mixer="attn", ffn="dense", window=None),
    ),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    use_post_norm=True,
    scale_embed=True,
    act="gelu",
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=4,
    subquadratic=True,
)
