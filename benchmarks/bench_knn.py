"""Sparse k-NN PaLD vs the best dense path: the n x k sweep (ISSUE 5).

Each n gets one row for the measured-best dense path (``pald.plan`` with
``method="auto"`` — the tuning-cache crossover pick) and one row per k for
``method="knn"``.  The knn timing is the full API cost: neighbor
selection + sparse cohesion + dense scatter, so the speedup column is
what a caller switching ``method=`` actually observes.

Dense cost grows O(n^3); at the largest n each dense cell is measured
with a single post-warmup run (``iters=1``) to keep the --fast suite
bounded, which is noisier but the gap measured here is orders of
magnitude, not percent.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pald

from .common import random_distance_matrix, time_fn


def run(ns=(1024, 4096), ks=(16, 32, 64), iters: int = 2) -> list[dict]:
    rows: list[dict] = []
    for n in ns:
        D = jnp.asarray(random_distance_matrix(n))
        it = 1 if n >= 4096 else iters
        p = pald.plan(D)
        t_dense = time_fn(lambda: p.execute(D), iters=it)
        rows.append({"n": n, "k": "-", "method": f"dense/{p.method}",
                     "seconds": round(t_dense, 4), "speedup_vs_dense": 1.0})
        for k in ks:
            if k > n - 1:
                continue
            pk = pald.plan(D, method="knn", k=k)
            t = time_fn(lambda: pk.execute(D), iters=max(it, 2))
            rows.append({"n": n, "k": k, "method": "knn",
                         "seconds": round(t, 4),
                         "speedup_vs_dense": round(t_dense / t, 1)})
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run(), header="knn: sparse k-NN PaLD vs best dense path")
