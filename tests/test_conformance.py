"""Cross-method conformance matrix: every PaLD path vs the entry-wise oracle.

One parametrized suite runs every (method, schedule, block, metric, n)
combination against ``core/reference.py`` — replacing the previous ad-hoc
per-method agreement tests and covering the fused features path from day
one.  The n grid deliberately includes the degenerate (n=1), minimal
(n=2), sub-block (n=7), non-multiple (n=33) and multi-block non-multiple
(n=130) regimes, so every padding / tiling branch is exercised.

The oracle is ``pald_pairwise_reference(normalize=True)`` computed in
float64; on the tie-free gaussian draws every tie mode returns identical
results, so those cells pin the default mode only.  The TIE-HEAVY axis
(integer distances, quantized embeddings, duplicated feature rows) runs
every ``ties`` mode against its own oracle — the input class on which the
paths used to disagree (DESIGN.md §9); before PR 3 the oracle only ever
saw tie-free draws, which is how that bug class shipped.
"""
import functools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import features, pald, reference
from repro.core.ties import TIE_MODES

NS = (1, 2, 7, 33, 130)
BLOCKS = (16, 64)

# (method, schedule) cells of pald.cohesion; dense ignores block entirely so
# it gets a single row rather than one per block
BLOCKED_PATHS = [
    ("pairwise", "dense"),
    ("triplet", "dense"),
    ("kernel", "dense"),
    ("kernel", "tri"),
]


@functools.lru_cache(maxsize=None)
def _case(n: int):
    """(X, D, C_reference) for one n — shared across the whole matrix."""
    rng = np.random.default_rng(100 + n)
    X = rng.normal(size=(n, 4))
    D = np.sqrt(((X[:, None, :] - X[None, :, :]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    Cref = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
    return X.astype(np.float32), D, Cref


@pytest.mark.parametrize("n", NS)
def test_dense_matches_reference(n):
    _, D, Cref = _case(n)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    assert C.dtype == np.float32
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("method,schedule", BLOCKED_PATHS)
def test_blocked_paths_match_reference(method, schedule, block, n):
    """Each cell vs the oracle — and bitwise vs the explicit engine route
    (one resolution, one executor: ``plan(...).execute`` IS the facade)."""
    _, D, Cref = _case(n)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method=method,
                                 schedule=schedule, block=block))
    assert C.dtype == np.float32
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)
    p = pald.plan(jnp.asarray(D), method=method, schedule=schedule,
                  block=block)
    Cp = np.asarray(p.execute(jnp.asarray(D)))
    np.testing.assert_array_equal(C, Cp)  # bitwise: same plan, same executor


# ---------------------------------------------------------------------------
# fused features path: ISSUE 2 acceptance — from_features(X, metric=m) must
# match cohesion(cdist_reference(X, m)) within 1e-5 for all four metrics,
# for both the jnp fused fallback and the bit-faithful interpret kernels
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("metric", features.METRICS)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_fused_matches_materialized(metric, impl, n):
    X, _, _ = _case(n)
    Cmat = np.asarray(pald.cohesion(
        features.cdist_reference(X, metric=metric), method="dense"))
    C = np.asarray(pald.from_features(
        jnp.asarray(X), metric=metric, block=16, block_z=16, impl=impl))
    assert C.dtype == np.float32
    np.testing.assert_allclose(C, Cmat, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", features.METRICS)
def test_fused_matches_entrywise_reference(metric):
    """End to end vs the O(n^3) oracle on the metric's own distances."""
    X, _, _ = _case(33)
    D = np.asarray(features.cdist_reference(X, metric=metric), np.float64)
    Cref = reference.pald_pairwise_reference(D, ties="ignore", normalize=True)
    C = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                      block=16, block_z=16))
    np.testing.assert_allclose(C, Cref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("metric", features.METRICS)
def test_materialized_methods_from_features(metric):
    """from_features with a non-fused method materializes D and must agree
    with the fused result (same metric, same data)."""
    X, _, _ = _case(33)
    Cf = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                       block=16, block_z=16))
    for method in ("dense", "pairwise", "triplet", "kernel"):
        Cm = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                           method=method, block=16))
        np.testing.assert_allclose(Cm, Cf, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sparse knn cells: the dense-agreement story.  method="knn" is an
# APPROXIMATION for k < n-1 (its own oracle lives in tests/test_knn.py);
# what belongs in the conformance matrix is the convergence contract:
# at k = n-1 the neighborhood restriction is the identity and the result
# must equal method="dense" BITWISE (the executor runs the dense path
# outright there), with the error decaying monotonically on the way.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", NS)
def test_knn_full_k_matches_dense_bitwise(n):
    _, D, _ = _case(n)
    Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense"))
    Ck = np.asarray(pald.cohesion(jnp.asarray(D), method="knn",
                                  k=max(n - 1, 1)))
    np.testing.assert_array_equal(Ck, Cd)


@pytest.mark.parametrize("n", (33, 130))
def test_knn_converges_to_dense(n):
    _, D, Cref = _case(n)
    last = np.inf
    for k in (max(n // 8, 1), n // 2, n - 2):
        C = np.asarray(pald.cohesion(jnp.asarray(D), method="knn", k=k))
        err = np.abs(C - Cref).max()
        assert err <= last + 1e-7
        last = err
    assert last < 5e-3  # k = n-2: only the last-rank pair set differs


@pytest.mark.parametrize("metric", features.METRICS)
def test_knn_from_features_full_k_matches_dense(metric):
    X, _, _ = _case(33)
    Cd = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                       method="dense"))
    Ck = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                       method="knn", k=32))
    np.testing.assert_array_equal(Ck, Cd)


# ---------------------------------------------------------------------------
# tie-heavy axis: integer distances, quantized embeddings, duplicated rows —
# × every ties mode × every (method, schedule).  Inputs are integer-valued
# so all distance arithmetic is exact in f32 and the f64 oracle sees the
# same tie structure as the optimized paths.
# ---------------------------------------------------------------------------
TIE_KINDS = ("integer", "quantized", "duplicates")


@functools.lru_cache(maxsize=None)
def _tie_case(kind: str):
    """(X or None, D_float64) for one tie-heavy input kind."""
    rng = np.random.default_rng(300)
    if kind == "integer":
        # raw integer distance matrix (e.g. edit distances, graph hops):
        # 5 distinct values over 153 pairs
        A = rng.integers(1, 6, size=(18, 18))
        D = np.triu(A, 1)
        return None, (D + D.T).astype(np.float64)
    if kind == "quantized":
        # rounded embeddings: integer grid points in 3-d
        X = rng.integers(-4, 5, size=(18, 3)).astype(np.float32)
    else:  # duplicates: exact zero-distance ties
        base = rng.integers(-4, 5, size=(12, 3)).astype(np.float32)
        X = np.vstack([base, base[:6]])
    D = np.asarray(features.cdist_reference(X, metric="sqeuclidean"),
                   np.float64)
    return X, D


@functools.lru_cache(maxsize=None)
def _tie_ref(kind: str, ties: str):
    _, D = _tie_case(kind)
    return reference.pald_pairwise_reference(D, ties=ties, normalize=True)


@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("kind", TIE_KINDS)
@pytest.mark.parametrize("method,schedule",
                         [("dense", "dense")] + BLOCKED_PATHS)
def test_tie_modes_match_reference(kind, ties, method, schedule):
    _, D = _tie_case(kind)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method=method,
                                 schedule=schedule, block=8, ties=ties))
    np.testing.assert_allclose(C, _tie_ref(kind, ties), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ties", TIE_MODES)
@pytest.mark.parametrize("metric", features.METRICS)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_fused_tie_modes_match_reference(metric, impl, ties):
    """Duplicated feature rows (exact zero-distance ties) through the fused
    pipeline, all four metrics: fused tile distances must reproduce the
    oracle's tie structure bit-for-bit."""
    X, _ = _tie_case("duplicates")
    D = np.asarray(features.cdist_reference(X, metric=metric), np.float64)
    Cref = reference.pald_pairwise_reference(D, ties=ties, normalize=True)
    C = np.asarray(pald.from_features(jnp.asarray(X), metric=metric,
                                      block=8, block_z=8, impl=impl,
                                      ties=ties))
    np.testing.assert_allclose(C, Cref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ties", TIE_MODES)
def test_quantized_from_features_tie_modes(ties):
    """Quantized (rounded) embeddings via from_features: ties across
    distinct point pairs, not just duplicates."""
    X, D = _tie_case("quantized")
    C = np.asarray(pald.from_features(jnp.asarray(X), metric="sqeuclidean",
                                      block=8, block_z=8, ties=ties))
    np.testing.assert_allclose(C, _tie_ref("quantized", ties),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# weight-functional axis: the new families (core/weights.py) on every cell.
# The oracle is the un-blocked jnp einsum composition (kernels/ref.py) —
# structurally independent of the blocked/Pallas paths under test — run on
# the TIE-HEAVY inputs, where smooth functionals actually differ from the
# built-ins.  Cross-impl agreement (jnp vs interpret) rides the same cells.
# ---------------------------------------------------------------------------
NEW_WEIGHTS = ("soft", "kernelized")


@functools.lru_cache(maxsize=None)
def _weight_ref(kind: str, weight: str):
    from repro.kernels import ref as _ref

    _, D = _tie_case(kind)
    Dj = jnp.asarray(D, jnp.float32)
    U = _ref.focus_ref(Dj, ties=weight)
    C = _ref.cohesion_ref(Dj, _ref.weights_ref(U), ties=weight)
    return np.asarray(C / max(D.shape[0] - 1, 1))


@pytest.mark.parametrize("weight", NEW_WEIGHTS)
@pytest.mark.parametrize("kind", TIE_KINDS)
@pytest.mark.parametrize("method,schedule",
                         [("dense", "dense")] + BLOCKED_PATHS)
def test_weight_functionals_match_einsum_oracle(kind, weight, method,
                                                schedule):
    _, D = _tie_case(kind)
    C = np.asarray(pald.cohesion(jnp.asarray(D), method=method,
                                 schedule=schedule, block=8, weight=weight))
    np.testing.assert_allclose(C, _weight_ref(kind, weight),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("weight", NEW_WEIGHTS)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_weight_functionals_fused_cell(weight, impl):
    """New functionals through the fused feature pipeline (zero kernel
    forks: the same closed expressions trace into the fused tile body)."""
    X, _ = _tie_case("duplicates")
    Cd = np.asarray(pald.from_features(jnp.asarray(X), metric="sqeuclidean",
                                       method="dense", weight=weight))
    C = np.asarray(pald.from_features(jnp.asarray(X), metric="sqeuclidean",
                                      block=8, block_z=8, impl=impl,
                                      weight=weight))
    np.testing.assert_allclose(C, Cd, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("weight", NEW_WEIGHTS)
@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_weight_functionals_knn_cell(weight, impl):
    """knn at full k is the identity restriction for ANY functional — the
    gathered-neighborhood tile must reproduce the dense result."""
    _, D = _tie_case("integer")
    n = D.shape[0]
    Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense",
                                  weight=weight))
    Ck = np.asarray(pald.cohesion(jnp.asarray(D), method="knn", k=n - 1,
                                  impl=impl, block=8, weight=weight))
    np.testing.assert_allclose(Ck, Cd, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("weight", NEW_WEIGHTS)
def test_weight_functionals_batched(weight):
    """The uniform batch layer with a functional: batched == per-item loop,
    chunked == unchunked bitwise."""
    D = _batch_case(33, 3)
    kw = dict(method="kernel", block=16, weight=weight)
    Cb = np.asarray(pald.cohesion(jnp.asarray(D), **kw))
    for i in range(3):
        Ci = np.asarray(pald.cohesion(jnp.asarray(D[i]), **kw))
        np.testing.assert_allclose(Cb[i], Ci, rtol=1e-6, atol=1e-7)
    Cb2 = np.asarray(pald.cohesion(jnp.asarray(D), batch=2, **kw))
    np.testing.assert_array_equal(Cb, Cb2)


# ---------------------------------------------------------------------------
# batched API: the engine's uniform (B, ...) layer on EVERY cell — distance
# input (B, n, n) for all four methods incl. the Pallas tri pipeline, and
# feature input (B, n, d) for the fused path.  Batched must equal the
# per-item loop; chunked (batch=) must equal unchunked bit-for-bit.
# ---------------------------------------------------------------------------
BATCH_NS = (7, 33)
BATCH_BS = (1, 3)


@functools.lru_cache(maxsize=None)
def _batch_case(n: int, B: int):
    rng = np.random.default_rng(500 + 10 * n + B)
    X = rng.normal(size=(B, n, 3))
    D = np.sqrt(((X[:, :, None, :] - X[:, None, :, :]) ** 2).sum(-1))
    for i in range(B):
        np.fill_diagonal(D[i], 0.0)
    return D.astype(np.float32)


@pytest.mark.parametrize("B", BATCH_BS)
@pytest.mark.parametrize("n", BATCH_NS)
@pytest.mark.parametrize("method,schedule",
                         [("dense", "dense")] + BLOCKED_PATHS)
def test_batched_cohesion_matches_loop(method, schedule, n, B):
    D = _batch_case(n, B)
    kw = dict(method=method, schedule=schedule)
    if method != "dense":
        kw["block"] = 16
    Cb = np.asarray(pald.cohesion(jnp.asarray(D), **kw))
    assert Cb.shape == (B, n, n) and Cb.dtype == np.float32
    for i in range(B):
        Ci = np.asarray(pald.cohesion(jnp.asarray(D[i]), **kw))
        np.testing.assert_allclose(Cb[i], Ci, rtol=1e-6, atol=1e-7)
    # chunked execution is a pure re-chunking of the same computation
    Cb2 = np.asarray(pald.cohesion(jnp.asarray(D), batch=2, **kw))
    np.testing.assert_array_equal(Cb, Cb2)


def test_batched_cohesion_rejects_bad_rank_and_batch():
    with pytest.raises(ValueError):
        pald.cohesion(jnp.zeros((2, 3, 4, 4)))
    with pytest.raises(ValueError):
        pald.cohesion(jnp.zeros((2, 4, 4)), batch=0)
    with pytest.raises(ValueError):
        pald.cohesion(jnp.zeros((2, 4, 5)))  # non-square items


def test_batched_matches_loop():
    rng = np.random.default_rng(7)
    Xb = rng.normal(size=(4, 21, 3)).astype(np.float32)
    Cb = np.asarray(pald.from_features(jnp.asarray(Xb), metric="euclidean",
                                       block=16, block_z=16))
    assert Cb.shape == (4, 21, 21) and Cb.dtype == np.float32
    for i in range(4):
        Ci = np.asarray(pald.from_features(jnp.asarray(Xb[i]),
                                           metric="euclidean",
                                           block=16, block_z=16))
        np.testing.assert_allclose(Cb[i], Ci, rtol=1e-6, atol=1e-7)
    # micro-batched execution is a pure chunking of the same computation
    Cb2 = np.asarray(pald.from_features(jnp.asarray(Xb), metric="euclidean",
                                        block=16, block_z=16, batch=3))
    np.testing.assert_allclose(Cb, Cb2, rtol=0, atol=0)


def test_batched_rejects_bad_rank_and_batch():
    X = jnp.zeros((2, 3, 4, 5))
    with pytest.raises(ValueError):
        pald.from_features(X)
    with pytest.raises(ValueError):
        pald.from_features(jnp.zeros((4, 8, 2)), batch=0)


def test_unknown_metric_rejected():
    with pytest.raises(ValueError):
        features.cdist_reference(jnp.zeros((4, 2)), metric="chebyshev")


def test_impl_only_configurable_for_fused():
    # silently dropping an explicit impl would let a test believe it
    # exercised a path it didn't; materialized methods must reject it
    with pytest.raises(ValueError):
        pald.from_features(jnp.zeros((8, 2)), method="dense", impl="interpret")


# ---------------------------------------------------------------------------
# n=1 is a fixed point of every path: no pairs, all-zero C, never nan
# ---------------------------------------------------------------------------
def test_n1_all_paths_zero_not_nan():
    D = jnp.zeros((1, 1))
    for method in ("dense", "pairwise", "triplet", "kernel"):
        C = np.asarray(pald.cohesion(D, method=method, block=16))
        assert C.shape == (1, 1) and np.all(C == 0.0), method
    C = np.asarray(pald.from_features(jnp.ones((1, 3)), block=16, block_z=16))
    assert C.shape == (1, 1) and np.all(C == 0.0)
    Cr = reference.pald_pairwise_reference(np.zeros((1, 1)), normalize=True)
    assert np.all(Cr == 0.0) and not np.isnan(Cr).any()
