"""Correctness of every PaLD path against the entry-wise references."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import analysis, pald, pairwise, reference, triplet


def test_reference_pairwise_equals_triplet(small_D):
    Cp = reference.pald_pairwise_reference(small_D, ties="ignore")
    Ct = reference.pald_triplet_reference(small_D)
    np.testing.assert_allclose(Cp, Ct, atol=1e-12)


# The per-method / per-size / per-block agreement tests that used to live
# here are superseded by the exhaustive matrix in tests/test_conformance.py.


def test_tie_handling_modes():
    # three collinear points with an exact tie: d(0,1)=d(1,2)=1, d(0,2)=2
    D = np.array([[0.0, 1.0, 2.0], [1.0, 0.0, 1.0], [2.0, 1.0, 0.0]])
    Cs = reference.pald_pairwise_reference(D, ties="split")
    Ci = reference.pald_pairwise_reference(D, ties="ignore")
    Cd = reference.pald_pairwise_reference(D, ties="drop")
    # z=1 ties between x=0 and y=2 in the (0,2) focus
    assert Cs[0, 1] == pytest.approx(Ci[0, 1] + Cd[0, 1] - Ci[0, 1] + 0.5 / 3)
    # drop: total support strictly below split/ignore
    assert Cd.sum() < Cs.sum()
    assert Cd.sum() < Ci.sum()
    # vectorized paths implement 'drop' semantics on exact ties
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense", normalize=False))
    np.testing.assert_allclose(C, Cd, rtol=1e-6, atol=1e-7)


def test_local_depths_and_total_mass(small_D):
    n = small_D.shape[0]
    C = np.asarray(pald.cohesion(jnp.asarray(small_D), method="dense"))
    depths = np.asarray(pald.local_depths(jnp.asarray(C)))
    assert depths.shape == (n,)
    assert (depths > 0).all() and (depths < 1).all()
    # sum of local depths == n/2 exactly (tie-free): each of the C(n,2)
    # pairs hands out total support 1, normalized by 1/(n-1)
    assert np.sum(C) == pytest.approx(n / 2, rel=1e-5)


def test_local_focus_dense_matches_reference(small_D):
    U = np.asarray(pairwise.local_focus_dense(jnp.asarray(small_D)))
    # strict comparisons exclude the pair itself (d_xx=0<d_xy, d_yy=0<d_xy
    # both count: u >= 2)
    Uref = reference.local_focus_reference(small_D)
    np.testing.assert_array_equal(U[~np.eye(len(U), dtype=bool)],
                                  Uref[~np.eye(len(U), dtype=bool)])
    assert (U[~np.eye(len(U), dtype=bool)] >= 2).all()


def test_block_symmetric_equals_blocked_pairwise(small_D):
    Dp, n0 = pald.pad_distance_matrix(jnp.asarray(small_D, jnp.float32), 16)
    nv = jnp.asarray(n0)
    Ca = np.asarray(pairwise.pald_blocked(Dp, block=16, n_valid=nv))[:n0, :n0]
    Cb = np.asarray(triplet.pald_block_symmetric(Dp, block=16, n_valid=nv))[:n0, :n0]
    np.testing.assert_allclose(Ca, Cb, rtol=1e-5, atol=1e-6)


def test_communities_two_clusters(clustered_D):
    C = np.asarray(pald.cohesion(jnp.asarray(clustered_D), method="dense"))
    comms = analysis.communities(C)
    # no strong-tie community may straddle the two planted clusters (PaLD's
    # universal threshold may split a cluster further — that's fine — but it
    # must never merge points across the 40-sigma gap)
    for c in comms:
        in_a = sum(1 for i in c if i < 12)
        assert in_a == 0 or in_a == len(c), f"mixed community {c}"
    # and the clusters are not shattered into singletons
    assert len(comms[0]) >= 5


def test_strong_ties_symmetric(small_D):
    C = np.asarray(pald.cohesion(jnp.asarray(small_D), method="dense"))
    S = analysis.strong_ties(C)
    np.testing.assert_allclose(S, S.T)
    assert (np.diag(S) == 0).all()
    tau = analysis.universal_threshold(C)
    assert ((S == 0) | (S >= tau)).all()


def test_top_ties(clustered_D):
    C = np.asarray(pald.cohesion(jnp.asarray(clustered_D), method="dense"))
    ties = analysis.top_ties(C, 0, k=5)
    assert len(ties) == 5
    # strongest ties of a cluster-0 point are inside cluster 0
    assert all(i < 12 for i, _ in ties[:3])
    # sorted descending
    vals = [v for _, v in ties]
    assert vals == sorted(vals, reverse=True)
