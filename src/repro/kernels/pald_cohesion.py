"""Pallas TPU kernel for PaLD pass 2: cohesion accumulation.

    C[x, z] = sum_y support_weight(D[x,z], D[y,z], D[x,y]) * W[x,y]

with W = 1/U (zero diagonal / padded entries; computed outside the kernel so
the reciprocal is done once — the paper's "precompute reciprocals" trick)
and the tie-mode support predicate shared with every other path
(``core/ties.py``; the default ``ties='drop'`` is the classic strict
``(d_xz < d_yz) & (d_xz < d_xy)``).

Grid (nx, nz, ny) with the y-reduction innermost: the output block C[X, Z]
stays resident in VMEM across all y steps.  The kernel updates unit-stride
(bx, bz) rows of C — the TPU translation of the paper's "updating columns of
C instead" stride-1 optimization (their C is updated column-wise because the
z loop streams columns; our block layout makes the streamed dim contiguous).

``ties='ignore'`` needs the global-index tiebreak: callers pass ``XW``
(mx, my) float32, 1.0 where global index x > global index y, which rides the
same BlockSpec as W.  The rectangular form cannot derive it from grid
position (distributed callers own arbitrary row offsets), so it is an
explicit input rather than an iota.

VMEM = D_XZ + C_XZ + D_YZ + D_XY + W_XY (+ XW_XY for 'ignore')
     = 3*bx*bz + 2*bx*by (+ bx*by) floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ties import DEFAULT_TIES, support_weight

__all__ = ["cohesion_pallas"]


def _cohesion_kernel(dxz_ref, dyz_ref, dxy_ref, w_ref, c_ref, *, ties):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]  # (bx, bz)
    dyz = dyz_ref[...]  # (by, bz)
    dxy = dxy_ref[...]  # (bx, by)
    w = w_ref[...]      # (bx, by)
    by = dxy.shape[1]

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)   # (1, bz)  d_yz
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)   # (bx, 1) d_xy
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)      # (bx, 1)
        g = support_weight(dxz, row, thr, ties)                 # (bx, bz)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


def _cohesion_kernel_xw(dxz_ref, dyz_ref, dxy_ref, w_ref, xw_ref, c_ref, *, ties):
    """ties='ignore' variant: one extra (bx, by) tiebreak tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    dxz = dxz_ref[...]
    dyz = dyz_ref[...]
    dxy = dxy_ref[...]
    w = w_ref[...]
    xw = xw_ref[...]    # (bx, by) 1.0 where global x index > global y index
    by = dxy.shape[1]

    def body(y, acc):
        row = jax.lax.dynamic_slice_in_dim(dyz, y, 1, axis=0)
        thr = jax.lax.dynamic_slice_in_dim(dxy, y, 1, axis=1)
        wy = jax.lax.dynamic_slice_in_dim(w, y, 1, axis=1)
        xwy = jax.lax.dynamic_slice_in_dim(xw, y, 1, axis=1) > 0.5  # (bx, 1)
        g = support_weight(dxz, row, thr, ties, xwy)
        return acc + g * wy

    add = jax.lax.fori_loop(0, by, body, jnp.zeros_like(c_ref))
    c_ref[...] += add


@functools.partial(jax.jit, static_argnames=("block_x", "block_z", "block_y",
                                             "interpret", "ties"))
def cohesion_general_pallas(
    DXZ: jnp.ndarray,  # (mx, mz)
    DYZ: jnp.ndarray,  # (my, mz)
    DXY: jnp.ndarray,  # (mx, my)
    W: jnp.ndarray,    # (mx, my)
    XW: jnp.ndarray | None = None,  # (mx, my) tiebreak, ties='ignore' only
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
    ties: str = DEFAULT_TIES,
) -> jnp.ndarray:
    """C (mx, mz) = sum_y support_weight(DXZ, DYZ[y], DXY[:,y]) * W[:,y].

    Rectangular form for distributed per-device compute; the square
    sequential case passes D three times.  ``ties='ignore'`` additionally
    requires ``XW`` (1.0 where global x index > global y index).
    """
    mx, mz = DXZ.shape
    my = DYZ.shape[0]
    assert DYZ.shape[1] == mz and DXY.shape == (mx, my) and W.shape == (mx, my)
    assert mx % block_x == 0 and mz % block_z == 0 and my % block_y == 0
    grid = (mx // block_x, mz // block_z, my // block_y)
    pair_spec = pl.BlockSpec((block_x, block_y), lambda i, j, k: (i, k))
    in_specs = [
        pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),  # DXZ
        pl.BlockSpec((block_y, block_z), lambda i, j, k: (k, j)),  # DYZ
        pair_spec,                                                 # DXY
        pair_spec,                                                 # W
    ]
    args = [DXZ.astype(jnp.float32), DYZ.astype(jnp.float32),
            DXY.astype(jnp.float32), W.astype(jnp.float32)]
    if ties == "ignore":
        if XW is None:
            raise ValueError("ties='ignore' needs XW (global-index tiebreak)")
        assert XW.shape == (mx, my)
        in_specs.append(pair_spec)                                 # XW
        args.append(XW.astype(jnp.float32))
        kernel = functools.partial(_cohesion_kernel_xw, ties=ties)
    else:
        kernel = functools.partial(_cohesion_kernel, ties=ties)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_x, block_z), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mx, mz), jnp.float32),
        interpret=interpret,
    )(*args)


def cohesion_pallas(
    D: jnp.ndarray,
    W: jnp.ndarray,
    *,
    block_x: int = 128,
    block_z: int = 512,
    block_y: int = 128,
    interpret: bool = False,
    ties: str = DEFAULT_TIES,
    XW: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Square cohesion matrix (un-normalized, sequential case)."""
    return cohesion_general_pallas(
        D, D, D, W, XW, block_x=block_x, block_z=block_z, block_y=block_y,
        interpret=interpret, ties=ties
    )
