"""Test-support utilities shipped with the library.

``repro.testing.faults`` is the fault-injection harness: context managers
that arm the named fault points threaded through the engine and kernels
(``core/resilience.fault_point``), simulate OOM, and corrupt or lock the
tuning cache — the machinery behind ``tests/test_faults.py`` and available
to downstream consumers hardening their own integration.
"""
from . import faults  # noqa: F401

__all__ = ["faults"]
