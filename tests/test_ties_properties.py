"""Hypothesis properties over matrices WITH ties (guarded, own module).

The main property suite (``test_pald_properties.py``) deterministically
jitters its draws to kill duplicates — which is exactly how the tri-schedule
tie disagreement shipped.  This strategy draws distances from a small
integer alphabet so ties are guaranteed by pigeonhole, and lives in its own
module so the ``importorskip`` guard (hypothesis is an optional dependency)
cannot take the deterministic regression tests in ``test_ties.py`` down
with it.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pald, reference
from repro.core.ties import TIE_MODES
from repro.core.weights import registered_weights, resolve_weight

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def tied_distance_matrices(draw, nmin=4, nmax=12, values=4):
    """Symmetric integer distance matrix with positive off-diagonals drawn
    from {1..values}: n*(n-1)/2 >= 6 pairs over <= 4 values forces ties."""
    n = draw(st.integers(nmin, nmax))
    flat = draw(st.lists(st.integers(1, values),
                         min_size=n * (n - 1) // 2,
                         max_size=n * (n - 1) // 2))
    D = np.zeros((n, n))
    D[np.triu_indices(n, 1)] = flat
    return D + D.T


@settings(max_examples=15, deadline=None)
@given(tied_distance_matrices(), st.sampled_from(TIE_MODES))
def test_tied_draws_match_reference(D, ties):
    Cref = reference.pald_pairwise_reference(D, ties=ties, normalize=True)
    Cd = np.asarray(pald.cohesion(jnp.asarray(D), method="dense", ties=ties))
    np.testing.assert_allclose(Cd, Cref, rtol=1e-5, atol=1e-6)
    Ct = np.asarray(pald.cohesion(jnp.asarray(D), method="kernel",
                                  schedule="tri", block=8, ties=ties))
    np.testing.assert_allclose(Ct, Cref, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(tied_distance_matrices())
def test_tied_draws_mass_laws(D):
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    split = reference.pald_pairwise_reference(D, ties="split").sum()
    ignore = reference.pald_pairwise_reference(D, ties="ignore").sum()
    drop = reference.pald_pairwise_reference(D, ties="drop").sum()
    assert abs(split - pairs) < 1e-9
    assert abs(ignore - pairs) < 1e-9
    assert drop <= pairs + 1e-9


# the mass law generalized: it is a declared PROPERTY of a functional, not
# a fact about the three historical modes — quantify over every registered
# functional that declares it (user-registered families included for free)
_MASS_CONSERVING = tuple(
    name for name in registered_weights()
    if resolve_weight(name).conserves_mass
)


@settings(max_examples=10, deadline=None)
@given(tied_distance_matrices(), st.sampled_from(_MASS_CONSERVING))
def test_declared_mass_conservation(D, name):
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    total = float(np.asarray(
        pald.cohesion(jnp.asarray(D), method="dense", normalize=False,
                      weight=name)).sum())
    assert abs(total - pairs) < 1e-3 * pairs


@settings(max_examples=10, deadline=None)
@given(tied_distance_matrices(),
       st.sampled_from(tuple(n for n in registered_weights()
                             if n not in TIE_MODES)))
def test_new_functionals_mass_bounded(D, name):
    """Every functional distributes at most weight 1 per pair."""
    n = D.shape[0]
    pairs = n * (n - 1) / 2
    C = np.asarray(pald.cohesion(jnp.asarray(D), method="dense",
                                 normalize=False, weight=name))
    assert np.all(C >= -1e-6)
    assert C.sum() <= pairs * (1 + 1e-4)
