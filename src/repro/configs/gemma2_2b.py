"""gemma2-2b — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local(4096-window)/global alternating attention, attn+final logit softcaps,
sandwich norms, sqrt(d) embedding scale, GeGLU.  [arXiv:2408.00118; hf]

long_500k runs: the sliding-window layers keep O(window) caches; global
layers hold the 500k KV cache sharded over the mesh — decode is O(L) reads.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    pattern=(
        LayerSpec(mixer="attn", ffn="dense", window=4096),  # local
        LayerSpec(mixer="attn", ffn="dense", window=None),  # global
    ),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    use_post_norm=True,
    scale_embed=True,
    act="gelu",
    sharding_profile="fsdp",
    remat="full",
    train_microbatches=4,
    subquadratic=True,  # half the stack is sliding-window
)
