"""Training driver: mesh setup, sharded state, checkpoint/restart, logging.

Runs real steps on whatever devices exist (CPU in this container, TPU pod in
production — same code path).  Fault tolerance:

* atomic async checkpoints every ``--ckpt-every`` steps;
* on startup the latest complete checkpoint is restored **with the current
  mesh's shardings** — restarting on a different device count (elastic
  scaling after node failure) Just Works because the checkpoint format is
  mesh-free (host numpy + manifest);
* the data pipeline is a pure function of (seed, step): a restarted job
  replays the exact stream, so loss curves are restart-exact.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import checkpointer
from repro.configs.base import reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch import mesh as meshlib
from repro.optim import adamw
from repro.sharding import partition
from repro.train import train_step as ts


def build_mesh(spec: str):
    if spec == "production":
        return meshlib.make_production_mesh()
    if spec == "production-multipod":
        return meshlib.make_production_mesh(multi_pod=True)
    dims = tuple(int(x) for x in spec.split("x"))
    names = ("pod", "data", "model")[-len(dims):] if len(dims) > 1 else ("data",)
    return meshlib.make_test_mesh(dims, names)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = build_mesh(args.mesh)
    print(f"[train] {cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = adamw.AdamWConfig(
        lr_peak=args.lr, warmup_steps=args.warmup, total_steps=args.steps
    )
    step_fn = ts.make_train_step(cfg, opt_cfg, microbatches=args.microbatches)

    # ---- sharded init ------------------------------------------------------
    with mesh:
        cap = {}

        def build(k):
            state, specs = ts.init_state(cfg, k)
            cap["specs"] = specs
            return state

        abstract = jax.eval_shape(build, jax.random.PRNGKey(args.seed))
        psh = partition.param_shardings(
            cap["specs"]["params"], cfg.sharding_profile, mesh,
            abstract["params"],
        )
        shardings = {
            "params": psh,
            "opt": {"m": psh, "v": psh},
            "step": NamedSharding(mesh, P()),
        }
        init_jit = jax.jit(build, out_shardings=shardings)
        state = init_jit(jax.random.PRNGKey(args.seed))

        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = checkpointer.AsyncCheckpointer(args.ckpt_dir)
            restored, at = checkpointer.restore_latest(
                args.ckpt_dir, abstract, shardings
            )
            if restored is not None:
                state, start = restored, at + 1
                print(f"[train] restored step {at} from {args.ckpt_dir}")

        bspec = partition.batch_pspec(mesh, args.batch)
        data = SyntheticTokens(
            cfg.vocab_size, args.seq, args.batch,
            seed=args.seed, mesh=mesh, batch_spec=bspec,
        )
        step_jit = jax.jit(step_fn, donate_argnums=(0,))

        t0 = time.time()
        tokens_done = 0
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            if cfg.modality in ("audio", "vlm"):
                # modality stub: embeddings instead of tokens (frontend is
                # precomputed per the brief); labels stay token ids
                emb = jax.random.normal(
                    jax.random.PRNGKey(step),
                    (args.batch, args.seq, cfg.d_model), jnp.float32,
                ) * 0.02
                batch = {"embeds": emb, "labels": batch["labels"]}
            state, metrics = step_jit(state, batch)
            tokens_done += args.batch * args.seq
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                dt = time.time() - t0
                print(
                    f"  step {step:5d} loss {float(m['loss']):8.4f} "
                    f"gnorm {float(m['grad_norm']):7.3f} lr {float(m['lr']):.2e} "
                    f"tok/s {tokens_done/max(dt,1e-9):,.0f}"
                )
            if ckpt and step > 0 and step % args.ckpt_every == 0:
                ckpt.save(step, state)
        if ckpt:
            ckpt.save(args.steps - 1, state)
            ckpt.wait()
            print(f"[train] final checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
