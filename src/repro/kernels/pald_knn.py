"""Pallas kernel for the sparse k-NN PaLD pipeline.

One grid axis, one pass: each grid step loads the (block, k) neighbor
distances, the (block, k, k) gathered neighbor-to-neighbor tile and the
(block, k) neighbor indices of one row block, and emits that block's
(block, k+1) sparse cohesion values.  Unlike the dense kernels there is no
cross-row reduction — the directed-pair knn formulation keeps every row's
focus sizes AND support local to its own neighborhood (``core/knn.py``
module docstring) — so focus and cohesion fuse into a single kernel with
no intermediate U/W round-trip through HBM.

The tile body is ``core.knn.knn_values_tile``, the same traced function
the blocked-jnp fallback (``kernels/ops._knn_values_jnp``) runs, so the
two impls are bit-faithful to each other by construction; the only
in-kernel addition is deriving the ``ties='ignore'`` index tiebreak from
the grid position (global row iota vs the loaded neighbor indices),
exactly as the dense square kernels do.

The gathered tile ``G`` is produced OUTSIDE the kernel (a dense-D fancy
gather or a per-chunk feature recompute, ``kernels/ops.pald_knn``): a
data-dependent gather from HBM inside a Pallas body would need per-index
DMA orchestration for an O(n * k^2) array that is small enough (205 MB at
n = 50k, k = 32) to stage in HBM anyway.

TPU alignment: Mosaic wants 128-lane last dims, so the entry point pads
the neighbor axis k up to the lane quantum (+inf distances, index 0) and
the value output up to ``_out_cols`` lanes; ``knn_values_tile`` masks the
padded columns out of the focus count and pair weights via ``k_valid``,
and the caller slices both paddings away.  Interpret mode (CPU tests)
runs unpadded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.knn import knn_values_tile
from repro.core.weights import DEFAULT_TIES, resolve_weight

__all__ = ["knn_values_pallas"]

_LANE = 128


def _out_cols(k: int, interpret: bool) -> int:
    """Lane-aligned width of the value output (k+1 columns on CPU)."""
    return k + 1 if interpret else -(-(k + 1) // _LANE) * _LANE


def _knn_kernel(dn_ref, g_ref, idx_ref, out_ref, *, block, k_valid, ties,
                n_cols):
    dn = dn_ref[...]                                  # (block, k)
    g = g_ref[...]                                    # (block, k, k)
    k = dn.shape[1]
    ow = None
    if ties.needs_index_tiebreak:
        rows = pl.program_id(0) * block + jax.lax.broadcasted_iota(
            jnp.int32, (block, k), 0)
        ow = rows > idx_ref[...]
    vals = knn_values_tile(dn, g, ow, ties,
                           k_valid=k_valid if k_valid < k else None)
    pad = n_cols - (k + 1)
    if pad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((block, pad), jnp.float32)], axis=1)
    out_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("block", "k_valid", "ties",
                                             "interpret"))
def knn_values_pallas(
    dn: jnp.ndarray,       # (m, k) neighbor distances (k possibly lane-padded)
    g: jnp.ndarray,        # (m, k, k) gathered neighbor-to-neighbor tiles
    idx: jnp.ndarray,      # (m, k) int32 neighbor indices
    *,
    block: int = 128,
    k_valid: int,
    ties=DEFAULT_TIES,
    interpret: bool = False,
) -> jnp.ndarray:
    """Sparse cohesion values (m, >= k+1) — caller slices to (n, k_valid+1).

    ``m`` must be a multiple of ``block`` (padded rows carry +inf neighbor
    distances and are sliced off by the caller); ``k_valid`` is the number
    of real neighbor columns when k was lane-padded.  Columns 0..k_valid
    of the output are [self, nbr_0, ..., nbr_{k_valid-1}]; everything past
    that (padded neighbors + lane fill) is junk/zero to slice away."""
    ties = resolve_weight(ties)
    m, k = dn.shape
    assert m % block == 0 and g.shape == (m, k, k) and idx.shape == (m, k)
    n_cols = _out_cols(k, interpret)
    kernel = functools.partial(_knn_kernel, block=block, k_valid=k_valid,
                               ties=ties, n_cols=n_cols)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, n_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_cols), jnp.float32),
        interpret=interpret,
    )(dn.astype(jnp.float32), g.astype(jnp.float32), idx.astype(jnp.int32))
