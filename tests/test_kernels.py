"""Pallas kernel sweeps: shapes x dtypes x block sizes vs the jnp oracles.

Kernels run in interpret mode (kernel body executed in Python on CPU —
bit-faithful to what Mosaic would run on TPU).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.pald_cohesion import cohesion_general_pallas
from repro.kernels.pald_focus import focus_general_pallas

from conftest import euclidean_distance_matrix


def _D(rng, n, dtype=np.float32):
    X = rng.normal(size=(n, 4))
    return euclidean_distance_matrix(X).astype(dtype)


@pytest.mark.parametrize("n,blk,blkz", [
    (32, 8, 8), (32, 16, 32), (64, 16, 16), (64, 32, 64),
    (128, 32, 128), (128, 128, 128), (96, 32, 96),
])
def test_focus_kernel_sweep(rng, n, blk, blkz):
    D = jnp.asarray(_D(rng, n))
    U = focus_general_pallas(D, D, D, block_x=blk, block_y=blk, block_z=blkz,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(U), np.asarray(ref.focus_ref(D)))


@pytest.mark.parametrize("n,blk,blkz", [
    (32, 8, 8), (32, 16, 32), (64, 16, 16), (64, 32, 64),
    (128, 32, 128), (96, 32, 96),
])
def test_cohesion_kernel_sweep(rng, n, blk, blkz):
    D = jnp.asarray(_D(rng, n))
    U = ref.focus_ref(D)
    W = ref.weights_ref(U)
    C = cohesion_general_pallas(D, D, D, W, block_x=blk, block_z=blkz,
                                block_y=blk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(C), np.asarray(ref.cohesion_ref(D, W)), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float64])
def test_kernel_dtypes(rng, dtype):
    """Inputs of any float dtype are compared in fp32 inside the kernel."""
    D32 = jnp.asarray(_D(rng, 64))
    D = D32.astype(dtype)
    U = focus_general_pallas(D, D, D, block_x=32, block_y=32, block_z=64,
                             interpret=True)
    Uref = ref.focus_ref(D.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(U), np.asarray(Uref))


@pytest.mark.parametrize("mx,my,mz", [(32, 64, 96), (64, 32, 32), (96, 32, 64)])
def test_rectangular_general_forms(rng, mx, my, mz):
    """The distributed algorithms call the rectangular forms with different
    row/col block sources; verify against a dense rectangular oracle."""
    DXZ = jnp.asarray(rng.normal(size=(mx, mz)).astype(np.float32) ** 2)
    DYZ = jnp.asarray(rng.normal(size=(my, mz)).astype(np.float32) ** 2)
    DXY = jnp.asarray(rng.normal(size=(mx, my)).astype(np.float32) ** 2)
    W = jnp.asarray(rng.random((mx, my)).astype(np.float32))

    m = (DXZ[:, None, :] < DXY[:, :, None]) | (DYZ[None, :, :] < DXY[:, :, None])
    Uref = m.sum(axis=-1).astype(np.float32)
    U = focus_general_pallas(DXZ, DYZ, DXY, block_x=16, block_y=16, block_z=16,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(U), np.asarray(Uref))

    g = (DXZ[:, None, :] < DYZ[None, :, :]) & (DXZ[:, None, :] < DXY[:, :, None])
    Cref = jnp.einsum("xyz,xy->xz", g.astype(jnp.float32), W)
    C = cohesion_general_pallas(DXZ, DYZ, DXY, W, block_x=16, block_y=16,
                                block_z=16, interpret=True)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cref), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,blk,blkz", [(64, 16, 16), (96, 32, 32), (64, 32, 64)])
def test_focus_tri_schedule(rng, n, blk, blkz):
    """The upper-triangular scalar-prefetch schedule (paper's triplet
    symmetry at block level) is exact vs the dense oracle."""
    from repro.kernels.pald_focus_tri import focus_tri_pallas
    D = jnp.asarray(_D(rng, n))
    U = focus_tri_pallas(D, block=blk, block_z=blkz, interpret=True)
    np.testing.assert_allclose(np.asarray(U), np.asarray(ref.focus_ref(D)))


def test_focus_tri_via_ops(rng):
    D = jnp.asarray(_D(rng, 64))
    U1 = ops.focus(D, block=32, block_z=32, impl="interpret", schedule="tri")
    U2 = ops.focus(D, block=32, block_z=32, impl="interpret")
    np.testing.assert_allclose(np.asarray(U1), np.asarray(U2))


def test_ops_jnp_fallback_matches_interpret(rng):
    D = jnp.asarray(_D(rng, 64))
    U_i = ops.focus(D, block=32, block_z=64, impl="interpret")
    U_j = ops.focus(D, block=32, block_z=64, impl="jnp")
    np.testing.assert_allclose(np.asarray(U_i), np.asarray(U_j))
    W = ref.weights_ref(U_i)
    C_i = ops.cohesion_from_weights(D, W, block=32, block_z=64, impl="interpret")
    C_j = ops.cohesion_from_weights(D, W, block=32, block_z=64, impl="jnp")
    np.testing.assert_allclose(np.asarray(C_i), np.asarray(C_j), rtol=1e-6, atol=1e-6)


def test_full_pipeline_pald(rng):
    D = jnp.asarray(_D(rng, 64))
    C = ops.pald(D, block=32, block_z=64, impl="interpret", normalize=True)
    U = ref.focus_ref(D)
    Cref = ref.cohesion_ref(D, ref.weights_ref(U)) / (64 - 1)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cref), rtol=1e-6, atol=1e-7)


def test_pick_block():
    assert ops._pick_block(96, 32) == 32
    assert ops._pick_block(96, 50) == 48
    assert ops._pick_block(7, 32) == 7
    assert ops._pick_block(100, 33) == 25
