"""Elastic-restart policy: mesh re-selection + resume-with-reshard."""
import numpy as np
import pytest

import jax

from repro import configs
from repro.configs.base import reduced
from repro.checkpoint import checkpointer
from repro.runtime import elastic
from repro.train import train_step as ts

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices"
)


def test_choose_mesh_shapes():
    m8 = elastic.choose_mesh(8, target_model=4)
    assert dict(m8.shape) == {"data": 2, "model": 4}
    m6 = elastic.choose_mesh(6, target_model=4)
    # model holds at 4, data shrinks to 1 (2 devices idle)
    assert dict(m6.shape) == {"data": 1, "model": 4}
    m3 = elastic.choose_mesh(3, target_model=16)
    assert dict(m3.shape) == {"data": 1, "model": 2}
    m1 = elastic.choose_mesh(1)
    assert dict(m1.shape) == {"data": 1, "model": 1}


def test_resume_after_shrink(tmp_path):
    """Train on 8 devices, 'lose' half the fleet, resume on 4."""
    cfg = reduced(configs.get("llama3.2-3b"))
    cap = {}

    def build(k):
        state, specs = ts.init_state(cfg, k)
        cap["specs"] = specs
        return state

    abstract = jax.eval_shape(build, jax.random.PRNGKey(0))

    mesh8 = elastic.choose_mesh(8, target_model=2)
    with mesh8:
        sh = elastic.state_shardings(cfg, mesh8, abstract, cap["specs"])
        state = jax.jit(build, out_shardings=sh)(jax.random.PRNGKey(0))
        step = jax.jit(ts.make_train_step(cfg))
        from repro.data.pipeline import SyntheticTokens
        data = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)
        for i in range(3):
            state, _ = step(state, data.batch_at(i))
        checkpointer.save(str(tmp_path), 2, state)

    # resume on a 4-device mesh
    mesh4 = elastic.choose_mesh(4, target_model=2)
    with mesh4:
        restored, at, mesh = elastic.resume(
            cfg, str(tmp_path), abstract, cap["specs"], mesh=mesh4)
        assert at == 2
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and training continues on the smaller mesh
        step4 = jax.jit(ts.make_train_step(cfg))
        from repro.data.pipeline import SyntheticTokens
        data = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)
        restored, metrics = step4(restored, data.batch_at(3))
        assert np.isfinite(float(metrics["loss"]))
