"""Quickstart: PaLD in five lines + the knobs that matter.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import analysis, pald


def main() -> None:
    # two communities with VERY different scales — absolute-distance methods
    # need per-dataset tuning here; PaLD does not
    rng = np.random.default_rng(0)
    tight = rng.normal(size=(15, 2)) * 0.1
    loose = rng.normal(size=(25, 2)) * 5.0 + 30.0
    X = np.vstack([tight, loose])
    D = np.sqrt(((X[:, None] - X[None]) ** 2).sum(-1))

    # --- the whole API ----------------------------------------------------
    C = pald.cohesion(jnp.asarray(D))                 # cohesion matrix
    depths = pald.local_depths(C)                     # l_x = sum_z c_xz
    comms = analysis.communities(np.asarray(C))       # strong-tie components
    # NB: analysis.universal_threshold assumes the NORMALIZED C (the
    # default normalize=True above carries the 1/(n-1) factor)

    print(f"n={len(X)}  sum(l_x)={float(depths.sum()):.2f}  (= n/2 exactly)")
    print(f"universal threshold tau={analysis.universal_threshold(np.asarray(C)):.4f}")
    print(f"communities found: {[len(c) for c in comms if len(c) > 1]}")

    # method selection: 'dense' (vectorized), 'pairwise' (blocked Fig.5),
    # 'triplet' (block-symmetric), 'kernel' (Pallas TPU kernels;
    # interpret-mode on CPU)
    for method in ("dense", "pairwise", "triplet", "kernel"):
        Cm = pald.cohesion(jnp.asarray(D), method=method)
        assert np.allclose(np.asarray(Cm), np.asarray(C), atol=1e-5)
    print("all four methods agree ✓")

    # --- the execution plan: resolve once, run anywhere -------------------
    # every knob (auto method, "auto" tiles, impl, tie semantics) is
    # resolved exactly once into a frozen plan; cohesion()/from_features()
    # are plan(...).execute(x) underneath.  explain() shows what resolved
    # and where it came from (tuning cache hit / nearest-n / default) —
    # the thing to paste into a perf bug report.
    p = pald.plan(jnp.asarray(D), method="auto")
    info = p.explain()
    print(f"plan: method={info['method']} ({info['method_source']}), "
          f"block={info['block']}, padded n={info['padded_n']}, "
          f"executor={info['executor'].rsplit('.', 1)[-1]}")
    assert np.allclose(np.asarray(p.execute(jnp.asarray(D))), np.asarray(C))

    # batched serving shape: (B, n, n) -> (B, n, n) works on EVERY method
    # (the Pallas tri pipeline included); batch= bounds how many items are
    # vmapped per compiled call, i.e. peak memory ~ batch * n^2 floats
    Db = jnp.stack([jnp.asarray(D)] * 4)
    Cb4 = pald.cohesion(Db, method="kernel", schedule="tri", batch=2)
    print(f"batched cohesion: {Db.shape} -> {Cb4.shape}")

    # input validation lives at the same boundary: non-square / nonzero-diag
    # D always errors; check=True adds finite+symmetry+nonnegativity
    try:
        pald.cohesion(jnp.asarray(D) + 1.0)  # broken diagonal
    except ValueError as e:
        print(f"caught bad input: {str(e)[:60]}...")

    # --- straight from features (no D matrix) -----------------------------
    # the fused pipeline computes distance tiles in-register from feature
    # tiles: D never hits HBM.  metrics: sqeuclidean / euclidean / cosine /
    # manhattan
    Cf = pald.from_features(jnp.asarray(X), metric="euclidean")
    assert np.allclose(np.asarray(Cf), np.asarray(C), atol=1e-5)
    print("fused from-features path agrees ✓")

    # batched workloads vmap for free: (B, n, d) -> (B, n, n)
    Xb = jnp.stack([jnp.asarray(X)] * 3)
    Cb = pald.from_features(Xb, metric="euclidean", batch=2)
    print(f"batched from_features: {Xb.shape} -> {Cb.shape}")

    # --- tie handling (integer / quantized / duplicated data) -------------
    # exact distance ties get ONE semantic across every method and backend,
    # chosen by ties=:
    #   'drop'   (default) tied support goes to neither point — strict
    #            comparisons, cheapest, the paper's optimized convention
    #   'split'  ties split 0.5/0.5 (theoretical PaLD; conserves total
    #            cohesion mass exactly even on heavily tied data)
    #   'ignore' Algorithm 1's sequential tie-goes-to-y branch
    # On tie-free data (like X above) all three agree; on quantized data
    # they differ and 'split' is the principled choice.
    Xq = np.round(X)                       # quantized features -> exact ties
    Cq = {t: pald.from_features(jnp.asarray(Xq), ties=t)
          for t in ("drop", "split", "ignore")}
    spread = max(float(jnp.abs(Cq[a] - Cq[b]).max())
                 for a in Cq for b in Cq)
    mass = float(Cq["split"].sum()) * (len(Xq) - 1)
    print(f"tie modes on quantized data: max spread {spread:.4f}, "
          f"split mass {mass:.1f} (= n(n-1)/2 exactly)")

    # --- sparse k-NN restriction (the large-n escape hatch) ---------------
    # method="knn" restricts conflict foci to each point's k nearest
    # neighbors: O(n*k^2) work instead of O(n^3), exact at k = n-1
    # (examples/pald_knn_clusters.py runs it at n = 50,000)
    Cknn = pald.cohesion(jnp.asarray(D), method="knn", k=len(X) - 1)
    Cdense = pald.cohesion(jnp.asarray(D), method="dense")
    assert np.array_equal(np.asarray(Cknn), np.asarray(Cdense))  # bitwise at full k
    err = float(jnp.abs(pald.cohesion(jnp.asarray(D), method="knn", k=10) - C).max())
    print(f"knn restriction: exact at k=n-1 ✓, max error {err:.4f} at k=10")

    # strongest ties of point 0 (inside the tight community)
    print("top ties of point 0:", analysis.top_ties(np.asarray(C), 0, k=3))


if __name__ == "__main__":
    main()
