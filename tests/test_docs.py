"""CI docs lane: the documentation cannot rot (ISSUE 5).

Two guarantees over README.md and docs/guides.md (plus every other
tracked *.md):

1. every fenced ```python block executes green — blocks are
   concatenated per file (top to bottom, one process) so later blocks
   may build on earlier ones, exactly as a reader follows them;
2. every relative markdown link resolves to an existing file, and
   heading anchors (`file.md#section`) resolve to a real heading using
   GitHub's slug rules.
"""
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# documents whose python examples are executed (the user-facing surface)
EXECUTED_DOCS = ["README.md", os.path.join("docs", "guides.md")]
# documents whose links are checked
LINKED_DOCS = EXECUTED_DOCS + ["DESIGN.md", "ROADMAP.md", "CHANGES.md"]

_FENCE = re.compile(r"^```python[^\n]*\n(.*?)^```", re.M | re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.M)


def _python_blocks(path: str) -> str:
    with open(os.path.join(REPO, path)) as f:
        return "\n\n".join(m.group(1) for m in _FENCE.finditer(f.read()))


@pytest.mark.parametrize("doc", EXECUTED_DOCS)
def test_fenced_python_executes(doc):
    src = _python_blocks(doc)
    assert src.strip(), f"{doc} has no executable python examples"
    r = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True,
        timeout=480, cwd=REPO,
        env={"PYTHONPATH": os.path.join(REPO, "src"),
             "PATH": "/usr/bin:/bin", "HOME": os.path.expanduser("~"),
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )
    assert r.returncode == 0, f"{doc} examples failed:\n{r.stderr[-3000:]}"


def _slug(heading: str) -> str:
    """GitHub's heading→anchor rule: lowercase, drop punctuation, dashes."""
    heading = re.sub(r"[`*_]", "", heading.strip().lower())
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path) as f:
        return {_slug(h) for h in _HEADING.findall(f.read())}


@pytest.mark.parametrize("doc", LINKED_DOCS)
def test_no_dead_links(doc):
    src_path = os.path.join(REPO, doc)
    if not os.path.exists(src_path):
        pytest.skip(f"{doc} not present")
    with open(src_path) as f:
        text = f.read()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue  # external: well-formedness only, no network in CI
        target, _, anchor = target.partition("#")
        resolved = (src_path if not target
                    else os.path.normpath(
                        os.path.join(os.path.dirname(src_path), target)))
        assert os.path.exists(resolved), f"{doc}: dead link -> {target}"
        if anchor and resolved.endswith(".md"):
            assert anchor in _anchors(resolved), \
                f"{doc}: dead anchor -> {target}#{anchor}"
